//! End-to-end scenario matrix: hermetic, deterministically generated
//! workload presets modeled on the paper's per-use-case evaluation,
//! each bundling a synthetic [`ExitBank`], a platform description, a
//! traffic trace and a constraint set. Every preset runs the full
//! closed loop — architecture search → mapping co-search → analytic
//! simulation → stage-graph serving (`serve_synthetic`) — with no
//! artifacts and no PJRT, and emits a structured [`ScenarioReport`]
//! aggregated into `BENCH_scenarios.json` (CLI: `repro scenarios`).
//! Serving defaults to the synthetic backend; `--backend native`
//! ([`run_scenario_with`]) swaps in the pure-Rust SIMD kernels in
//! calibrated mode, leaving the deterministic report byte-identical
//! while the `timing` block measures real compute.
//!
//! | preset               | platform     | models the paper's…                              |
//! |----------------------|--------------|--------------------------------------------------|
//! | `kws_psoc6`          | psoc6        | speech-command detection on the MCU testbed      |
//! |                      |              | (2.5 s worst-case constraint, 59.67% fewer ops)  |
//! | `ecg_mcu`            | psoc6        | ECG monitoring: easy-majority distribution where |
//! |                      |              | **every** sample can exit early (74.9% energy /  |
//! |                      |              | 78.3% compute reduction)                         |
//! | `cifar_rk3588_cloud` | rk3588+cloud | CIFAR-10 distributed fog offload (up to 58.75%)  |
//! | `stress_fog`         | fog-cluster  | high-traffic fog serving: arrivals far above the |
//! |                      |              | first stage's service rate, queueing visible in  |
//! |                      |              | the executor's latency tail                      |
//! | `stress_fog_shed`    | fog-cluster  | the same regime with bounded queues: the DES     |
//! |                      |              | backpressure path sheds deterministically, with  |
//! |                      |              | exact `shed + completed == offered` accounting   |
//! | `multi_tenant_fog`   | fog-cluster  | four tenants sharing the fog ingress behind      |
//! |                      |              | per-tenant token buckets, with escalations       |
//! |                      |              | prioritized and a slack-resolved deadline —      |
//! |                      |              | rate limiting sheds (`shed_bucket`), queues don't|
//! | `overload_storm`     | fog-cluster  | bursty MMPP storm far above every local tier's   |
//! |                      |              | capacity, unbounded queues, absolute deadline:   |
//! |                      |              | the admission predictor (`shed_deadline`) is the |
//! |                      |              | only thing standing between storm and collapse   |
//!
//! The **fleet matrix** ([`fleet_all`], CLI: `repro scenarios --only
//! 'fleet_*'`, artifact `BENCH_scenarios_fleet.json`) scales the fog
//! preset out to replica fleets behind the deterministic
//! consistent-hash router ([`crate::coordinator::fleet`]):
//!
//! | preset            | fleet   | models…                                          |
//! |-------------------|---------|--------------------------------------------------|
//! | `fleet_fog`       | fog x4  | sharded fleet serving with a shared cloud tier   |
//! |                   |         | that cross-replica escalations contend on        |
//! | `fleet_diurnal`   | fog x4  | time-varying (diurnal tent-profile) arrivals     |
//! |                   |         | sweeping the fleet through load and lull         |
//! | `fleet_hotkey`    | fog x4  | skewed shard keys: 70% of traffic on two keys,   |
//! |                   |         | so ring ownership — not the mean rate — decides  |
//! |                   |         | which replica saturates                          |
//! | `fleet_rebalance` | fog x3  | mid-trace replica loss: epoch bump, survivors    |
//! |                   |         | absorb the keys, **exact** conservation          |
//! |                   |         | `completed + shed + rerouted == offered`         |
//!
//! The **mesh preset** ([`mesh_all`], CLI: `repro scenarios --only
//! mesh_cifar`, artifact `BENCH_scenarios_mesh.json`) exercises the
//! branch-and-bound mapping co-search at a scale the exhaustive
//! assignment sweep cannot touch:
//!
//! | preset       | platform      | models…                                         |
//! |--------------|---------------|-------------------------------------------------|
//! | `mesh_cifar` | mesh-accel-16 | CIFAR-style offload across a 16-tile            |
//! |              |               | accelerator mesh: up to 16^6 ≈ 16.7M            |
//! |              |               | assignments per exit subset — exhaustively      |
//! |              |               | intractable, seconds under branch-and-bound     |
//! |              |               | (`MapSearch::Auto` upgrades automatically)      |
//!
//! The **joint preset** ([`mesh_joint_all`], CLI: `repro scenarios
//! --only mesh_cifar_joint --joint`, artifact
//! `BENCH_scenarios_mesh_joint.json`) re-runs the same mesh workload
//! under the joint exits×assignment branch-and-bound
//! ([`crate::na::joint`]): every search-shaping knob mirrors
//! `mesh_cifar`, so the reports differ only by search regime, and the
//! per-entry `"joint"` block records the joint-vs-two-phase pricing
//! with `joint_cost <= two_phase_cost` enforced as a hard runtime
//! assertion.
//!
//! # Determinism
//!
//! A [`ScenarioReport`] is **bit-reproducible**: running a preset
//! twice — or at different search worker counts — yields byte-identical
//! [`ScenarioReport::deterministic_json`] output (asserted by
//! `tests/scenarios.rs`). Two ingredients make that hold:
//!
//! * the search core (`na::augment_prepared`) is deterministic for any
//!   worker count (PR 2's order-preserving reductions);
//! * the serving executor is a virtual-time discrete-event scheduler
//!   (`crate::coordinator`): completions, sheds, termination counts,
//!   per-request latencies and busy totals all come from the
//!   deterministic event clock — the scenario layer consumes its
//!   metrics directly, with no separate replay.
//!
//! Wall-clock timings (search/serve duration, throughput) are real and
//! therefore volatile; they live under the report's `"timing"` key,
//! which `deterministic_json` strips.

use std::collections::BTreeMap;
use std::time::Instant;

use anyhow::{bail, Result};

use crate::coordinator::{
    serve_fleet_synthetic, serve_native, serve_synthetic, ArrivalProcess, Backend, FleetConfig,
    FleetFailure, KeyDist, NativeOptions, QosConfig, ServeConfig,
};
use crate::graph::BlockGraph;
use crate::hw::{presets, Platform};
use crate::na::{self, ExitBank, ExitProfile, FlowConfig, TrainedExit};
use crate::sim::simulate;
use crate::util::json::Json;
use crate::util::rng::Rng;

/// How the synthetic calibration profiles of a scenario's exits are
/// shaped — the knob that turns "CIFAR-like mixed difficulty" into
/// "ECG-like easy majority".
#[derive(Debug, Clone, Copy)]
pub enum ConfidenceModel {
    /// Exit accuracy ramps with depth from `lo` to `hi`; confidences
    /// follow the shared [`ExitProfile::synthetic`] fixture (correct
    /// predictions more confident than wrong ones).
    Ramp { lo: f64, hi: f64 },
    /// Every sample is confident above the top of the threshold grid,
    /// so **any** configured cascade terminates all samples at its
    /// first exit — the paper's ECG regime where the easy majority is
    /// the whole distribution.
    EasyMajority { acc: f64 },
}

/// Synthetic arrival process the serving stage replays.
#[derive(Debug, Clone, Copy)]
pub struct TrafficTrace {
    /// Arrival rate, requests per second of sim time (the calm-state
    /// rate for an MMPP trace).
    pub arrival_rate_hz: f64,
    /// Requests in the full trace.
    pub n_requests: usize,
    /// Requests in `--smoke` mode (CI).
    pub smoke_n_requests: usize,
    /// Seed of the arrival/label/verdict RNGs.
    pub seed: u64,
    /// Arrival-process shape (Poisson or bursty MMPP).
    pub arrival: ArrivalProcess,
}

/// One hermetic workload preset: everything `run_scenario` needs.
#[derive(Debug, Clone)]
pub struct Scenario {
    pub name: &'static str,
    pub description: &'static str,
    pub graph: BlockGraph,
    pub platform: Platform,
    /// Seed of the synthetic exit bank (head weights + profiles).
    pub bank_seed: u64,
    /// Calibration samples per synthetic profile.
    pub n_cal: usize,
    pub confidence: ConfidenceModel,
    /// Worst-case latency constraint of the search, seconds.
    pub latency_constraint_s: f64,
    /// Scalarization weights of the threshold search.
    pub w_eff: f64,
    pub w_acc: f64,
    pub traffic: TrafficTrace,
    /// Serving queue bound, passed through to `ServeConfig::queue_cap`.
    /// `0` = unbounded (roomy: the preset must not shed on queue
    /// depth, though QoS admission policies may still shed); a
    /// positive value bounds the stage queues and lets the executor
    /// shed deterministically.
    pub queue_cap: usize,
    /// Admission-control policies, passed through to
    /// [`ServeConfig::qos`] (after [`Scenario::resolve_qos`] applies
    /// the slack below). Disabled by default.
    pub qos: QosConfig,
    /// Deadline expressed as a multiple of the searched solution's
    /// worst-case unloaded path latency. `0` = off; a positive slack
    /// overrides `qos.deadline_s` with `slack * worst_path_s` once the
    /// solution (and hence the analytic sim) is known — presets can
    /// state "2x the unloaded worst case" without hard-coding seconds.
    pub deadline_slack: f64,
    /// Run the joint exits×assignment branch-and-bound
    /// ([`FlowConfig::joint`]) instead of the two-phase pipeline.
    /// `false` on every base/fleet/mesh preset — those artifacts are
    /// bit-frozen; only the `mesh_cifar_joint` preset (own artifact)
    /// turns it on.
    pub joint: bool,
}

impl Scenario {
    /// Resolve the preset's QoS knobs against the searched solution's
    /// analytic worst-case path latency (the last stage's cumulative
    /// latency from `sim::simulate`).
    pub fn resolve_qos(&self, sim_worst_path_s: f64) -> QosConfig {
        let mut qos = self.qos;
        if self.deadline_slack > 0.0 {
            qos.deadline_s = self.deadline_slack * sim_worst_path_s;
        }
        qos
    }
}

/// Speech-command detection on the PSoC6 MCU testbed: 12-class
/// DS-CNN-scale model, the paper's 2.5 s worst-case constraint, and a
/// mixed-difficulty confidence ramp.
pub fn kws_psoc6() -> Scenario {
    Scenario {
        name: "kws_psoc6",
        description: "speech commands on the PSoC6 (2.5s constraint, mixed difficulty)",
        graph: BlockGraph::synthetic_resnet(12, 2),
        platform: presets::psoc6(),
        bank_seed: 101,
        n_cal: 400,
        confidence: ConfidenceModel::Ramp { lo: 0.55, hi: 0.90 },
        latency_constraint_s: 2.5,
        w_eff: 0.9,
        w_acc: 0.1,
        // one utterance every couple of seconds: sustainable on the
        // MCU (≈1.4 s/inference on the M0), the paper's always-on
        // keyword-spotting regime
        traffic: TrafficTrace {
            arrival_rate_hz: 0.5,
            n_requests: 4_000,
            smoke_n_requests: 400,
            seed: 7,
            arrival: ArrivalProcess::Poisson,
        },
        queue_cap: 0,
        qos: QosConfig::default(),
        deadline_slack: 0.0,
        joint: false,
    }
}

/// ECG monitoring on an MCU: easy-majority distribution — every
/// sample's confidence clears the whole threshold grid, so the
/// configured cascade terminates 100% of the traffic at its first
/// exit (the paper's 74.9% energy / 78.3% compute reduction regime).
pub fn ecg_mcu() -> Scenario {
    // compact 1-D ECG CNN: the ResNet cost shape with leaner
    // parameter/activation footprints, so the post-exit remainder fits
    // the M4F budget and the *shallowest* exit is mappable — the
    // paper's ECG regime (78.3% compute reduction) needs the first
    // boundary, not a mid-network one
    let mut graph = BlockGraph::synthetic_resnet(5, 2);
    graph.model = "synthetic_ecg_cnn".into();
    for b in &mut graph.blocks {
        b.param_bytes /= 4;
        b.act_bytes /= 2;
    }
    Scenario {
        name: "ecg_mcu",
        description: "ECG monitoring on an MCU (easy majority: all samples exit early)",
        graph,
        platform: presets::psoc6(),
        bank_seed: 202,
        n_cal: 400,
        confidence: ConfidenceModel::EasyMajority { acc: 0.98 },
        latency_constraint_s: 2.5,
        w_eff: 0.9,
        w_acc: 0.1,
        // ~one classification per heartbeat: continuous monitoring,
        // sustainable on either MCU core
        traffic: TrafficTrace {
            arrival_rate_hz: 1.2,
            n_requests: 5_000,
            smoke_n_requests: 500,
            seed: 11,
            arrival: ArrivalProcess::Poisson,
        },
        queue_cap: 0,
        qos: QosConfig::default(),
        deadline_slack: 0.0,
        joint: false,
    }
}

/// CIFAR-10 on the RK3588 + cloud platform: distributed fog offload
/// with no latency constraint, mixed difficulty, deeper graph.
pub fn cifar_rk3588_cloud() -> Scenario {
    Scenario {
        name: "cifar_rk3588_cloud",
        description: "CIFAR-10 fog offload on rk3588+cloud (unconstrained)",
        graph: BlockGraph::synthetic_resnet(10, 3),
        platform: presets::rk3588_cloud(),
        bank_seed: 303,
        n_cal: 500,
        confidence: ConfidenceModel::Ramp { lo: 0.45, hi: 0.92 },
        latency_constraint_s: f64::INFINITY,
        w_eff: 0.9,
        w_acc: 0.1,
        traffic: TrafficTrace {
            arrival_rate_hz: 30.0,
            n_requests: 3_000,
            smoke_n_requests: 300,
            seed: 13,
            arrival: ArrivalProcess::Poisson,
        },
        queue_cap: 0,
        qos: QosConfig::default(),
        deadline_slack: 0.0,
        joint: false,
    }
}

/// High-traffic fog serving: a four-tier platform and an arrival rate
/// far above the first stage's service rate, so the executor's latency
/// tail shows sustained queueing (the scaling stress case every
/// serving-path PR is measured against).
pub fn stress_fog() -> Scenario {
    Scenario {
        name: "stress_fog",
        description: "high-traffic serving on the four-tier fog cluster",
        graph: BlockGraph::synthetic_resnet(10, 4),
        platform: presets::fog_cluster(),
        bank_seed: 404,
        n_cal: 400,
        confidence: ConfidenceModel::Ramp { lo: 0.50, hi: 0.90 },
        latency_constraint_s: f64::INFINITY,
        w_eff: 0.9,
        w_acc: 0.1,
        traffic: TrafficTrace {
            arrival_rate_hz: 1_500.0,
            n_requests: 8_000,
            smoke_n_requests: 800,
            seed: 17,
            arrival: ArrivalProcess::Poisson,
        },
        queue_cap: 0,
        qos: QosConfig::default(),
        deadline_slack: 0.0,
        joint: false,
    }
}

/// Bounded-queue shedding: the fog platform swamped well beyond any
/// on-premise tier's service rate (the first segment serves at most
/// ~15.5k req/s even on the fog GPU, against 25k req/s offered) with
/// stage queues capped at 64 entries, so the executor's backpressure
/// path must shed — deterministically, with exact
/// `shed + completed == offered` accounting in the report.
pub fn stress_fog_shed() -> Scenario {
    Scenario {
        name: "stress_fog_shed",
        description: "bounded-queue overload on the fog cluster (deterministic shedding)",
        graph: BlockGraph::synthetic_resnet(10, 4),
        platform: presets::fog_cluster(),
        bank_seed: 505,
        n_cal: 400,
        confidence: ConfidenceModel::Ramp { lo: 0.50, hi: 0.90 },
        latency_constraint_s: f64::INFINITY,
        w_eff: 0.9,
        w_acc: 0.1,
        traffic: TrafficTrace {
            arrival_rate_hz: 25_000.0,
            n_requests: 6_000,
            smoke_n_requests: 600,
            seed: 23,
            arrival: ArrivalProcess::Poisson,
        },
        queue_cap: 64,
        qos: QosConfig::default(),
        deadline_slack: 0.0,
        joint: false,
    }
}

/// Four tenants sharing the fog ingress behind per-tenant token
/// buckets, escalations prioritized, and a deadline of 2x the
/// searched solution's unloaded worst-case path. The offered load
/// (2.4k req/s) is far above the aggregate bucket refill (4 tenants x
/// 120 tokens/s + 4 x 25 of burst), so rate limiting — not queue
/// depth — does the shedding: `shed_bucket > 0` while
/// `shed_queue == 0` by construction (queues are unbounded). The
/// search-shaping knobs mirror `stress_fog` exactly, so the searched
/// solution is identical and only the serving regime differs.
pub fn multi_tenant_fog() -> Scenario {
    Scenario {
        name: "multi_tenant_fog",
        description: "four tenants behind token buckets on the fog cluster (QoS shedding)",
        graph: BlockGraph::synthetic_resnet(10, 4),
        platform: presets::fog_cluster(),
        bank_seed: 404,
        n_cal: 400,
        confidence: ConfidenceModel::Ramp { lo: 0.50, hi: 0.90 },
        latency_constraint_s: f64::INFINITY,
        w_eff: 0.9,
        w_acc: 0.1,
        traffic: TrafficTrace {
            arrival_rate_hz: 2_400.0,
            n_requests: 6_000,
            smoke_n_requests: 600,
            seed: 29,
            arrival: ArrivalProcess::Poisson,
        },
        queue_cap: 0,
        qos: QosConfig {
            deadline_s: f64::INFINITY,
            priority_escalations: true,
            tenants: 4,
            bucket_rate_hz: 120.0,
            bucket_burst: 25.0,
        },
        deadline_slack: 2.0,
        joint: false,
    }
}

/// Bursty MMPP storm on the fog cluster: a calm rate already above
/// every local tier's first-segment capacity, ten-fold bursts on top,
/// **unbounded** queues and an absolute 15 ms deadline — the
/// deadline-aware admission predictor is the only shedding mechanism,
/// so `shed_deadline > 0` while `shed_queue == shed_bucket == 0` by
/// construction. The search-shaping knobs mirror `stress_fog_shed`
/// exactly, so the searched solution is identical and only the
/// serving regime differs.
pub fn overload_storm() -> Scenario {
    Scenario {
        name: "overload_storm",
        description: "MMPP burst storm with deadline admission on the fog cluster",
        graph: BlockGraph::synthetic_resnet(10, 4),
        platform: presets::fog_cluster(),
        bank_seed: 505,
        n_cal: 400,
        confidence: ConfidenceModel::Ramp { lo: 0.50, hi: 0.90 },
        latency_constraint_s: f64::INFINITY,
        w_eff: 0.9,
        w_acc: 0.1,
        traffic: TrafficTrace {
            arrival_rate_hz: 50_000.0,
            n_requests: 6_000,
            smoke_n_requests: 1_500,
            seed: 31,
            arrival: ArrivalProcess::Mmpp {
                burst_factor: 10.0,
                mean_burst_s: 0.002,
                mean_calm_s: 0.005,
            },
        },
        queue_cap: 0,
        qos: QosConfig {
            deadline_s: 0.015,
            priority_escalations: true,
            tenants: 0,
            bucket_rate_hz: 0.0,
            bucket_burst: 0.0,
        },
        deadline_slack: 0.0,
        joint: false,
    }
}

/// The full scenario matrix, in reporting order.
pub fn all() -> Vec<Scenario> {
    vec![
        kws_psoc6(),
        ecg_mcu(),
        cifar_rk3588_cloud(),
        stress_fog(),
        stress_fog_shed(),
        multi_tenant_fog(),
        overload_storm(),
    ]
}

/// CIFAR-style offload across the 16-tile accelerator mesh
/// ([`presets::mesh_accel`]). With five EE locations and sixteen
/// processors the mapping sweeps behind the search face up to
/// 16^6 ≈ 16.7M assignments per exit subset — far past the exhaustive
/// enumerator's [`crate::mapping::MAX_ASSIGNMENTS`] cap — so the
/// default [`crate::mapping::MapSearch::Auto`] strategy upgrades every
/// oversized sweep to branch-and-bound and the whole preset completes
/// in seconds. Kept out of [`all`] (own artifact,
/// `BENCH_scenarios_mesh.json`): the base matrix is pinned to the
/// paper's seven use cases.
pub fn mesh_cifar() -> Scenario {
    Scenario {
        name: "mesh_cifar",
        description: "CIFAR offload on the 16-tile mesh: B&B-scale mapping search",
        graph: BlockGraph::synthetic_resnet(10, 2),
        platform: presets::mesh_accel(),
        bank_seed: 606,
        n_cal: 400,
        confidence: ConfidenceModel::Ramp { lo: 0.50, hi: 0.90 },
        latency_constraint_s: f64::INFINITY,
        w_eff: 0.9,
        w_acc: 0.1,
        traffic: TrafficTrace {
            arrival_rate_hz: 200.0,
            n_requests: 4_000,
            smoke_n_requests: 400,
            seed: 53,
            arrival: ArrivalProcess::Poisson,
        },
        queue_cap: 0,
        qos: QosConfig::default(),
        deadline_slack: 0.0,
        joint: false,
    }
}

/// The mesh scenario matrix, in reporting order.
pub fn mesh_all() -> Vec<Scenario> {
    vec![mesh_cifar()]
}

/// Run every mesh preset in [`mesh_all`].
pub fn run_mesh_all(
    workers: usize,
    exec_workers: usize,
    smoke: bool,
    backend: Backend,
) -> Result<Vec<ScenarioReport>> {
    mesh_all()
        .iter()
        .map(|sc| run_scenario_with(sc, workers, exec_workers, smoke, backend))
        .collect()
}

/// Aggregate mesh reports into the `BENCH_scenarios_mesh.json`
/// document (same shell as [`bench_json`], `bench` name
/// `scenarios_mesh`). With `deterministic`, entries carry only the
/// byte-reproducible payload.
pub fn mesh_bench_json(reports: &[ScenarioReport], smoke: bool, deterministic: bool) -> Json {
    let entries = reports.iter().map(|r| {
        let mut j = if deterministic { r.deterministic_json() } else { r.to_json() };
        if let Json::Obj(m) = &mut j {
            m.remove("workers");
        }
        (r.scenario.clone(), j)
    });
    bench_doc("scenarios_mesh", smoke, entries.collect())
}

/// [`mesh_cifar`] with the joint exits×assignment branch-and-bound
/// turned on: identical graph, platform, bank seed, traffic and
/// weights, so any difference between its report and `mesh_cifar`'s
/// is attributable to the search regime alone. The report carries the
/// joint-vs-two-phase pricing (`joint_cost <= two_phase_cost` is a
/// hard runtime assertion in [`run_scenario_with`]), and lives in its
/// own artifact (`BENCH_scenarios_mesh_joint.json`) so the bit-frozen
/// `mesh_cifar` payload is untouched.
pub fn mesh_cifar_joint() -> Scenario {
    Scenario {
        name: "mesh_cifar_joint",
        description: "mesh_cifar under the joint exits x assignment branch-and-bound",
        joint: true,
        ..mesh_cifar()
    }
}

/// The joint-search scenario matrix, in reporting order.
pub fn mesh_joint_all() -> Vec<Scenario> {
    vec![mesh_cifar_joint()]
}

/// Run every joint preset in [`mesh_joint_all`].
pub fn run_mesh_joint_all(
    workers: usize,
    exec_workers: usize,
    smoke: bool,
    backend: Backend,
) -> Result<Vec<ScenarioReport>> {
    mesh_joint_all()
        .iter()
        .map(|sc| run_scenario_with(sc, workers, exec_workers, smoke, backend))
        .collect()
}

/// Aggregate joint reports into the `BENCH_scenarios_mesh_joint.json`
/// document (same shell as [`bench_json`], `bench` name
/// `scenarios_mesh_joint`). With `deterministic`, entries carry only
/// the byte-reproducible payload.
pub fn mesh_joint_bench_json(
    reports: &[ScenarioReport],
    smoke: bool,
    deterministic: bool,
) -> Json {
    let entries = reports.iter().map(|r| {
        let mut j = if deterministic { r.deterministic_json() } else { r.to_json() };
        if let Json::Obj(m) = &mut j {
            m.remove("workers");
        }
        (r.scenario.clone(), j)
    });
    bench_doc("scenarios_mesh_joint", smoke, entries.collect())
}

/// Calibration profile where every sample clears the top of the
/// threshold grid (0.95): confidences in [0.955, 0.999).
fn easy_profile(rng: &mut Rng, n: usize, acc: f64) -> ExitProfile {
    let mut conf = Vec::with_capacity(n);
    let mut correct = Vec::with_capacity(n);
    for _ in 0..n {
        correct.push(rng.f64() < acc);
        conf.push((0.955 + 0.044 * rng.f64()) as f32);
    }
    ExitProfile { location: 0, conf, pred: vec![0; n], correct }
}

/// Deterministic synthetic exit bank on an arbitrary graph: one
/// trained exit per EE location with seeded head weights, profiles
/// shaped by `confidence`, and a 0.96-accuracy final head. The one
/// shared fixture behind the scenario presets and the hermetic
/// parallel-search battery (`tests/parallel_search.rs`).
pub fn synthetic_bank(
    graph: &BlockGraph,
    seed: u64,
    n_cal: usize,
    confidence: ConfidenceModel,
) -> ExitBank {
    let mut rng = Rng::seeded(seed);
    let n_locs = graph.ee_locations.len();
    let mut exits = BTreeMap::new();
    let mut profiles = BTreeMap::new();
    let mut exit_accs = BTreeMap::new();
    for (i, &loc) in graph.ee_locations.iter().enumerate() {
        let prof = match confidence {
            ConfidenceModel::Ramp { lo, hi } => {
                let t = if n_locs <= 1 { 1.0 } else { i as f64 / (n_locs - 1) as f64 };
                ExitProfile::synthetic(&mut rng, n_cal, lo + (hi - lo) * t)
            }
            ConfidenceModel::EasyMajority { acc } => easy_profile(&mut rng, n_cal, acc),
        };
        let c = graph.blocks[loc].gap_dim;
        let k = graph.num_classes;
        exits.insert(
            loc,
            TrainedExit {
                location: loc,
                c,
                k,
                w: (0..c * k).map(|_| rng.f32() - 0.5).collect(),
                b: (0..k).map(|_| rng.f32() - 0.5).collect(),
                first_epoch_acc: prof.accuracy(),
                calibration_acc: prof.accuracy(),
                viable: true,
                epochs_run: 1,
            },
        );
        exit_accs.insert(loc, prof.accuracy());
        profiles.insert(loc, prof);
    }
    let final_profile = ExitProfile::synthetic(&mut rng, n_cal, 0.96);
    ExitBank {
        exits,
        profiles,
        final_profile,
        exit_accs,
        nonviable: Vec::new(),
        feature_cache_s: 0.0,
        exit_training_s: 0.0,
    }
}

/// [`synthetic_bank`] for a scenario preset.
pub fn build_bank(sc: &Scenario) -> ExitBank {
    synthetic_bank(&sc.graph, sc.bank_seed, sc.n_cal, sc.confidence)
}

/// Deterministic, worker-invariant digest of a joint-search run for
/// the scenario artifact: the two prices being compared plus the tree
/// counters proving how little of the cross-product the bound let the
/// search touch. (The [`na::SearchReport`] cache counters are *not*
/// here — they are shard-layout-dependent and belong to the bench's
/// 1-worker run only.)
#[derive(Debug, Clone, Copy)]
pub struct JointDigest {
    /// Joint winner's exact price `s(E*) + m(E*, A*)`.
    pub joint_cost: f64,
    /// The two-phase pipeline's winner priced through the identical
    /// objective; `joint_cost <= two_phase_cost` is asserted at run
    /// time.
    pub two_phase_cost: f64,
    pub subsets_considered: u64,
    pub subsets_pruned: u64,
    pub map_nodes: u64,
    pub map_leaves: u64,
}

impl JointDigest {
    fn to_json(self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("joint_cost".into(), Json::Num(self.joint_cost));
        m.insert("two_phase_cost".into(), Json::Num(self.two_phase_cost));
        m.insert("subsets_considered".into(), Json::Num(self.subsets_considered as f64));
        m.insert("subsets_pruned".into(), Json::Num(self.subsets_pruned as f64));
        m.insert("map_nodes".into(), Json::Num(self.map_nodes as f64));
        m.insert("map_leaves".into(), Json::Num(self.map_leaves as f64));
        Json::Obj(m)
    }
}

/// Per-preset outcome of the closed loop. Everything except the
/// `"timing"` block is bit-reproducible across runs and worker counts.
#[derive(Debug, Clone)]
pub struct ScenarioReport {
    pub scenario: String,
    pub platform: String,
    pub model: String,
    /// Search worker threads this run used (input parameter; excluded
    /// from [`Self::deterministic_json`] alongside the timings).
    pub workers: usize,
    pub n_requests: usize,
    pub arrival_rate_hz: f64,
    // --- search outcome -------------------------------------------------
    pub exits: Vec<usize>,
    pub assignment: Vec<usize>,
    pub thresholds: Vec<f64>,
    pub score: f64,
    pub candidates_kept: usize,
    pub evaluated_configs: u64,
    pub mapping_candidates: usize,
    /// Joint-search digest when the preset ran with
    /// [`Scenario::joint`]. `None` on the default two-phase path —
    /// and then absent from the JSON, so the bit-frozen default
    /// artifacts keep their exact key set.
    pub joint: Option<JointDigest>,
    pub expected_term_rates: Vec<f64>,
    /// Expected mean-ops reduction vs. the seed (always-full-backbone)
    /// baseline, percent: `100 * (1 - expected_mac_frac)`.
    pub mean_ops_reduction_pct: f64,
    // --- serving outcome ------------------------------------------------
    /// Same reduction measured from the served termination histogram.
    pub measured_ops_reduction_pct: f64,
    /// Share of served requests that terminated before the final head.
    pub early_term_pct: f64,
    pub completed: usize,
    /// Requests shed before service, all reasons (exact accounting:
    /// `shed + completed == n_requests` offered, and `shed` is the sum
    /// of the three reason counters below). Zero for every roomy
    /// no-QoS preset; deterministic and nonzero for `stress_fog_shed`
    /// (queue), `multi_tenant_fog` (bucket) and `overload_storm`
    /// (deadline).
    pub shed: usize,
    /// Sheds at a full bounded queue.
    pub shed_queue: usize,
    /// Sheds by the deadline-aware admission predictor.
    pub shed_deadline: usize,
    /// Fresh arrivals rejected by an empty per-tenant token bucket.
    pub shed_bucket: usize,
    /// Termination count per classifier (EEs then final).
    pub term_hist: Vec<usize>,
    pub accuracy: f64,
    pub mean_energy_mj: f64,
    /// Reserved device time per processor on the executor's virtual
    /// clock.
    pub proc_busy_s: Vec<f64>,
    /// End-to-end sim latency percentiles straight from the
    /// deterministic discrete-event executor.
    pub sim_latency_p50_s: f64,
    pub sim_latency_p99_s: f64,
    // --- queue telemetry (virtual-time, deterministic) -------------------
    /// Largest depth each stage queue reached.
    pub queue_max_depth: Vec<usize>,
    /// Time-weighted mean depth of each stage queue.
    pub queue_mean_depth: Vec<f64>,
    /// p99 sojourn (stage-queue entry to dispatch) per stage, seconds.
    pub sojourn_p99_s: Vec<f64>,
    /// Per-stage queue depth bucketed into fixed windows over the
    /// virtual horizon (max depth per window).
    pub queue_depth_series: Vec<Vec<usize>>,
    // --- volatile wall-clock measurements -------------------------------
    pub search_wall_s: f64,
    pub serve_wall_s: f64,
    pub throughput_rps: f64,
}

fn farr(v: &[f64]) -> Json {
    Json::Arr(v.iter().map(|&x| Json::Num(x)).collect())
}

fn uarr(v: &[usize]) -> Json {
    Json::Arr(v.iter().map(|&x| Json::Num(x as f64)).collect())
}

impl ScenarioReport {
    pub fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("scenario".into(), Json::Str(self.scenario.clone()));
        m.insert("platform".into(), Json::Str(self.platform.clone()));
        m.insert("model".into(), Json::Str(self.model.clone()));
        m.insert("workers".into(), Json::Num(self.workers as f64));
        m.insert("n_requests".into(), Json::Num(self.n_requests as f64));
        m.insert("arrival_rate_hz".into(), Json::Num(self.arrival_rate_hz));
        m.insert("exits".into(), uarr(&self.exits));
        m.insert("assignment".into(), uarr(&self.assignment));
        m.insert("thresholds".into(), farr(&self.thresholds));
        m.insert("score".into(), Json::Num(self.score));
        m.insert("candidates_kept".into(), Json::Num(self.candidates_kept as f64));
        m.insert("evaluated_configs".into(), Json::Num(self.evaluated_configs as f64));
        m.insert("mapping_candidates".into(), Json::Num(self.mapping_candidates as f64));
        if let Some(j) = self.joint {
            m.insert("joint".into(), j.to_json());
        }
        m.insert("expected_term_rates".into(), farr(&self.expected_term_rates));
        m.insert("mean_ops_reduction_pct".into(), Json::Num(self.mean_ops_reduction_pct));
        m.insert("measured_ops_reduction_pct".into(), Json::Num(self.measured_ops_reduction_pct));
        m.insert("early_term_pct".into(), Json::Num(self.early_term_pct));
        m.insert("completed".into(), Json::Num(self.completed as f64));
        m.insert("shed".into(), Json::Num(self.shed as f64));
        m.insert("shed_queue".into(), Json::Num(self.shed_queue as f64));
        m.insert("shed_deadline".into(), Json::Num(self.shed_deadline as f64));
        m.insert("shed_bucket".into(), Json::Num(self.shed_bucket as f64));
        m.insert("term_hist".into(), uarr(&self.term_hist));
        m.insert("accuracy".into(), Json::Num(self.accuracy));
        m.insert("mean_energy_mj".into(), Json::Num(self.mean_energy_mj));
        m.insert("proc_busy_s".into(), farr(&self.proc_busy_s));
        m.insert("sim_latency_p50_s".into(), Json::Num(self.sim_latency_p50_s));
        m.insert("sim_latency_p99_s".into(), Json::Num(self.sim_latency_p99_s));
        m.insert("queue_max_depth".into(), uarr(&self.queue_max_depth));
        m.insert("queue_mean_depth".into(), farr(&self.queue_mean_depth));
        m.insert("sojourn_p99_s".into(), farr(&self.sojourn_p99_s));
        m.insert(
            "queue_depth_series".into(),
            Json::Arr(self.queue_depth_series.iter().map(|s| uarr(s)).collect()),
        );
        let mut t = BTreeMap::new();
        t.insert("search_wall_s".into(), Json::Num(self.search_wall_s));
        t.insert("serve_wall_s".into(), Json::Num(self.serve_wall_s));
        t.insert("throughput_rps".into(), Json::Num(self.throughput_rps));
        m.insert("timing".into(), Json::Obj(t));
        Json::Obj(m)
    }

    /// [`Self::to_json`] minus the volatile keys (`timing`, `workers`):
    /// the byte-reproducible payload the determinism tests and the CI
    /// regression gate compare exactly.
    pub fn deterministic_json(&self) -> Json {
        let mut j = self.to_json();
        if let Json::Obj(m) = &mut j {
            m.remove("timing");
            m.remove("workers");
        }
        j
    }

    pub fn print(&self) {
        println!("=== {} — {} on {} ===", self.scenario, self.model, self.platform);
        println!(
            "  search: exits {:?} -> procs {:?} (score {:.4}, {} candidates, \
             {} configs, {} mappings, {:.2}s)",
            self.exits,
            self.assignment,
            self.score,
            self.candidates_kept,
            self.evaluated_configs,
            self.mapping_candidates,
            self.search_wall_s
        );
        if let Some(j) = &self.joint {
            println!(
                "  joint: cost {:.4} vs two-phase {:.4} ({} subsets, {} inner nodes)",
                j.joint_cost, j.two_phase_cost, j.subsets_considered, j.map_nodes
            );
        }
        println!(
            "  ops reduction vs seed: {:.2}% expected / {:.2}% measured \
             ({:.2}% early termination)",
            self.mean_ops_reduction_pct, self.measured_ops_reduction_pct, self.early_term_pct
        );
        println!(
            "  serving: {}/{} completed ({} shed) at {:.0} req/s arrival, \
             term hist {:?}, acc {:.4}",
            self.completed,
            self.n_requests,
            self.shed,
            self.arrival_rate_hz,
            self.term_hist,
            self.accuracy
        );
        if self.shed > 0 {
            println!(
                "  shed breakdown: {} queue-full / {} deadline / {} bucket \
                 | queue max depth {:?}",
                self.shed_queue, self.shed_deadline, self.shed_bucket, self.queue_max_depth
            );
        }
        println!(
            "  sim latency p50 {:.4}s p99 {:.4}s | mean energy {:.3}mJ | busy {:?}s",
            self.sim_latency_p50_s,
            self.sim_latency_p99_s,
            self.mean_energy_mj,
            self.proc_busy_s
                .iter()
                .map(|s| (s * 1e4).round() / 1e4)
                .collect::<Vec<_>>()
        );
    }
}

/// Run one preset through the full closed loop: synthetic bank →
/// `augment_prepared` (search + mapping co-search) → analytic sim →
/// `serve_synthetic` through the discrete-event executor, whose
/// metrics (latency percentiles, busy totals, sheds) are consumed
/// directly — the executor *is* the deterministic replay. `workers`
/// drives the search fan-out and `exec_workers` the executor's exec
/// plane (`1` = inline); the report's deterministic payload is
/// identical for every value of either.
pub fn run_scenario(
    sc: &Scenario,
    workers: usize,
    exec_workers: usize,
    smoke: bool,
) -> Result<ScenarioReport> {
    run_scenario_with(sc, workers, exec_workers, smoke, Backend::Synthetic)
}

/// [`run_scenario`] with an explicit serving backend. `Synthetic`
/// draws verdicts without arithmetic; `Native` runs the pure-Rust SIMD
/// kernels on the exec plane in calibrated mode, so its deterministic
/// report is byte-identical to the synthetic one while the wall-clock
/// `timing` block measures real multiply-accumulate throughput (smoke
/// runs use the tiny test-scale backbone, full runs the bench scale).
/// `Pjrt` is rejected: presets are hermetic and have no artifacts.
pub fn run_scenario_with(
    sc: &Scenario,
    workers: usize,
    exec_workers: usize,
    smoke: bool,
    backend: Backend,
) -> Result<ScenarioReport> {
    let bank = build_bank(sc);
    let cfg = FlowConfig {
        latency_constraint_s: sc.latency_constraint_s,
        w_eff: sc.w_eff,
        w_acc: sc.w_acc,
        workers,
        joint: sc.joint,
        ..FlowConfig::default()
    };
    let t0 = Instant::now();
    let out = na::augment_prepared(&bank, &sc.graph, sc.name, &sc.platform, &cfg, None)?;
    let search_wall_s = t0.elapsed().as_secs_f64();
    let sol = &out.solution;

    // the analytic sim of the searched solution feeds both the report
    // and the slack-resolved deadline, so it runs before serving
    let mapping = sol.mapping();
    let sim = simulate(&sc.graph, &mapping, &sc.platform);
    let worst_path_s = sim.stages.last().map(|s| s.cum_latency_s).unwrap_or(0.0);
    let qos = sc.resolve_qos(worst_path_s);

    let n_requests = if smoke { sc.traffic.smoke_n_requests } else { sc.traffic.n_requests };
    // per-sample serving; the preset's queue bound passes straight
    // through (0 = unbounded in the executor too, so roomy presets
    // cannot shed on queue depth)
    let scfg = ServeConfig {
        arrival_rate_hz: sc.traffic.arrival_rate_hz,
        n_requests,
        queue_cap: sc.queue_cap,
        batch_max: 1,
        seed: sc.traffic.seed,
        exec_workers,
        qos,
        arrival: sc.traffic.arrival,
    };
    let t0 = Instant::now();
    let m = match backend {
        Backend::Synthetic => serve_synthetic(&sc.graph, sol, &sc.platform, &scfg)?,
        Backend::Native => {
            let nopts = if smoke {
                NativeOptions::test(sc.bank_seed)
            } else {
                NativeOptions::bench(sc.bank_seed)
            };
            serve_native(&sc.graph, sol, &sc.platform, &scfg, &nopts)?
        }
        Backend::Pjrt => bail!(
            "{}: scenario presets are hermetic (no artifacts) — the pjrt backend \
             only applies to `repro serve`",
            sc.name
        ),
    };
    let serve_wall_s = t0.elapsed().as_secs_f64();
    if m.completed + m.shed != n_requests {
        bail!(
            "{}: request accounting broken ({} completed + {} shed != {} offered)",
            sc.name,
            m.completed,
            m.shed,
            n_requests
        );
    }
    if m.shed != m.shed_queue + m.shed_deadline + m.shed_bucket {
        bail!(
            "{}: shed breakdown broken ({} != {} + {} + {})",
            sc.name,
            m.shed,
            m.shed_queue,
            m.shed_deadline,
            m.shed_bucket
        );
    }
    if sc.queue_cap == 0 && m.shed_queue != 0 {
        bail!("{}: unbounded queues must not shed on depth ({} shed)", sc.name, m.shed_queue);
    }
    if sc.queue_cap == 0 && !qos.can_shed() && m.shed != 0 {
        bail!("{}: roomy queues without QoS must not shed ({} shed)", sc.name, m.shed);
    }
    if m.completed == 0 {
        bail!("{}: nothing served (all {} offered requests shed)", sc.name, n_requests);
    }

    if sc.joint != out.report.joint.is_some() {
        bail!("{}: joint flag and joint report disagree", sc.name);
    }
    let joint = out.report.joint.as_ref().map(|j| JointDigest {
        joint_cost: j.joint_cost,
        two_phase_cost: j.two_phase_cost,
        subsets_considered: j.stats.subsets_considered,
        subsets_pruned: j.stats.subsets_pruned,
        map_nodes: j.stats.map_nodes,
        map_leaves: j.stats.map_leaves,
    });
    if let Some(j) = &joint {
        // the two-phase pair lives inside the joint search space and
        // both sides are priced through the same objective, so this
        // holds exactly — any violation is a soundness bug, not noise
        if j.joint_cost > j.two_phase_cost {
            bail!(
                "{}: joint winner ({:.17}) worse than two-phase ({:.17})",
                sc.name,
                j.joint_cost,
                j.two_phase_cost
            );
        }
    }

    let total_macs = sc.graph.total_macs() as f64;
    let completed = m.completed as f64;
    let measured_macs: f64 = m
        .term_hist
        .iter()
        .zip(&sim.stages)
        .map(|(&c, st)| c as f64 * st.cum_macs as f64)
        .sum();
    let measured_frac = measured_macs / (completed * total_macs);
    let early = m.completed - m.term_hist.last().copied().unwrap_or(0);

    Ok(ScenarioReport {
        scenario: sc.name.to_string(),
        platform: sc.platform.name.clone(),
        model: sc.graph.model.clone(),
        workers: out.report.workers,
        n_requests,
        arrival_rate_hz: sc.traffic.arrival_rate_hz,
        exits: sol.exits.clone(),
        assignment: sol.assignment.clone(),
        thresholds: sol.thresholds.clone(),
        score: sol.score,
        candidates_kept: out.report.prune.kept,
        evaluated_configs: out.report.evaluated_configs,
        mapping_candidates: out.report.mapping_candidates,
        joint,
        expected_term_rates: sol.expected_term_rates.clone(),
        mean_ops_reduction_pct: 100.0 * (1.0 - sol.expected_mac_frac),
        measured_ops_reduction_pct: 100.0 * (1.0 - measured_frac),
        early_term_pct: 100.0 * early as f64 / completed,
        completed: m.completed,
        shed: m.shed,
        shed_queue: m.shed_queue,
        shed_deadline: m.shed_deadline,
        shed_bucket: m.shed_bucket,
        term_hist: m.term_hist.clone(),
        accuracy: m.quality.accuracy,
        mean_energy_mj: m.mean_energy_mj,
        proc_busy_s: m.proc_busy_s.clone(),
        sim_latency_p50_s: m.sim_latency.p50,
        sim_latency_p99_s: m.sim_latency.p99,
        queue_max_depth: m.queue_stats.iter().map(|q| q.max_depth).collect(),
        queue_mean_depth: m.queue_stats.iter().map(|q| q.mean_depth).collect(),
        sojourn_p99_s: m.queue_stats.iter().map(|q| q.sojourn.p99).collect(),
        queue_depth_series: m.queue_stats.iter().map(|q| q.depth_series.clone()).collect(),
        search_wall_s,
        serve_wall_s,
        throughput_rps: m.throughput_rps,
    })
}

/// Run every preset in [`all`] at the given worker counts.
pub fn run_all(workers: usize, exec_workers: usize, smoke: bool) -> Result<Vec<ScenarioReport>> {
    run_all_with(workers, exec_workers, smoke, Backend::Synthetic)
}

/// [`run_all`] with an explicit serving backend.
pub fn run_all_with(
    workers: usize,
    exec_workers: usize,
    smoke: bool,
    backend: Backend,
) -> Result<Vec<ScenarioReport>> {
    all().iter().map(|sc| run_scenario_with(sc, workers, exec_workers, smoke, backend)).collect()
}

/// Aggregate reports into the `BENCH_scenarios.json` document. Keeps
/// the wall-clock `timing` blocks (tracked with a tolerance band by
/// the CI regression gate) but drops `workers`: it defaults to the
/// machine's core count, and an environment-derived value must not
/// sit in an exact-match-gated artifact.
pub fn bench_json(reports: &[ScenarioReport], smoke: bool) -> Json {
    let entries = reports.iter().map(|r| {
        let mut j = r.to_json();
        if let Json::Obj(m) = &mut j {
            m.remove("workers");
        }
        (r.scenario.clone(), j)
    });
    bench_doc("scenarios", smoke, entries.collect())
}

/// [`bench_json`] carrying only the byte-reproducible payload per
/// entry (no `timing`, no `workers`) — for byte-diffing runs.
pub fn bench_json_deterministic(reports: &[ScenarioReport], smoke: bool) -> Json {
    bench_doc(
        "scenarios",
        smoke,
        reports.iter().map(|r| (r.scenario.clone(), r.deterministic_json())).collect(),
    )
}

/// Shared shell of every scenario bench document: `bench` name,
/// `fixture` tag and the per-preset `scenarios` map. One builder so
/// the base and fleet artifacts cannot drift structurally.
fn bench_doc(bench: &str, smoke: bool, scenarios: BTreeMap<String, Json>) -> Json {
    let mut top = BTreeMap::new();
    top.insert("bench".to_string(), Json::Str(bench.to_string()));
    top.insert(
        "fixture".to_string(),
        Json::Str(if smoke { "smoke" } else { "full" }.to_string()),
    );
    top.insert("scenarios".to_string(), Json::Obj(scenarios));
    Json::Obj(top)
}

// ---------------------------------------------------------------------------
// fleet scenario matrix
// ---------------------------------------------------------------------------

/// A fleet preset: a base [`Scenario`] (search shaping + traffic)
/// replicated behind the consistent-hash router per a
/// [`FleetConfig`]. All replicas serve the *same* searched solution —
/// the fleet scales the serving plane out, not the search.
#[derive(Debug, Clone)]
pub struct FleetScenario {
    pub base: Scenario,
    pub fleet: FleetConfig,
}

/// Shared base of every fleet preset: the `stress_fog` search-shaping
/// knobs (same graph, platform, bank seed and constraint set, so the
/// searched solution is identical across the whole fleet matrix and
/// to `stress_fog` itself) with preset-specific traffic and queueing.
fn fog_fleet_base(
    name: &'static str,
    description: &'static str,
    traffic: TrafficTrace,
    queue_cap: usize,
) -> Scenario {
    Scenario {
        name,
        description,
        graph: BlockGraph::synthetic_resnet(10, 4),
        platform: presets::fog_cluster(),
        bank_seed: 404,
        n_cal: 400,
        confidence: ConfidenceModel::Ramp { lo: 0.50, hi: 0.90 },
        latency_constraint_s: f64::INFINITY,
        w_eff: 0.9,
        w_acc: 0.1,
        traffic,
        queue_cap,
        qos: QosConfig::default(),
        deadline_slack: 0.0,
        joint: false,
    }
}

/// Four fog replicas behind the ring, cloud tier shared: uniform keys
/// spread ~4.8k req/s across the fleet while every replica's
/// escalations contend on one fleet-global cloud timeline.
pub fn fleet_fog() -> FleetScenario {
    FleetScenario {
        base: fog_fleet_base(
            "fleet_fog",
            "four fog replicas behind the hash ring, shared cloud tier",
            TrafficTrace {
                arrival_rate_hz: 4_800.0,
                n_requests: 8_000,
                smoke_n_requests: 800,
                seed: 37,
                arrival: ArrivalProcess::Poisson,
            },
            0,
        ),
        fleet: FleetConfig {
            replicas: 4,
            vnodes: 64,
            hash_seed: 0xF1EE_7001,
            shared_cloud: true,
            keys: KeyDist::Uniform,
            fail: None,
        },
    }
}

/// Time-varying arrivals: a diurnal tent profile sweeps the fleet
/// from lull (1.2k req/s) to six-fold peak every 50 ms of sim time,
/// so queue depths breathe with the cycle instead of settling into a
/// stationary regime.
pub fn fleet_diurnal() -> FleetScenario {
    FleetScenario {
        base: fog_fleet_base(
            "fleet_diurnal",
            "diurnal tent-profile arrivals sweeping the four-replica fleet",
            TrafficTrace {
                arrival_rate_hz: 1_200.0,
                n_requests: 8_000,
                smoke_n_requests: 800,
                seed: 41,
                arrival: ArrivalProcess::Diurnal {
                    period_s: 0.05,
                    peak_factor: 6.0,
                    phases: 8,
                },
            },
            0,
        ),
        fleet: FleetConfig {
            replicas: 4,
            vnodes: 64,
            hash_seed: 0xF1EE_7002,
            shared_cloud: true,
            keys: KeyDist::Uniform,
            fail: None,
        },
    }
}

/// Skewed shard keys: 70% of the traffic collapses onto two hot keys,
/// so ring ownership — not the fleet-mean rate — decides which
/// replica saturates its bounded queues while the cold replicas idle.
pub fn fleet_hotkey() -> FleetScenario {
    FleetScenario {
        base: fog_fleet_base(
            "fleet_hotkey",
            "hot-key skew: 70% of traffic on two keys, bounded queues",
            TrafficTrace {
                arrival_rate_hz: 48_000.0,
                n_requests: 6_000,
                smoke_n_requests: 600,
                seed: 43,
                arrival: ArrivalProcess::Poisson,
            },
            64,
        ),
        fleet: FleetConfig {
            replicas: 4,
            vnodes: 64,
            hash_seed: 0xF1EE_7003,
            shared_cloud: false,
            keys: KeyDist::Hotspot { hot_frac: 0.7, hot_keys: 2 },
            fail: None,
        },
    }
}

/// Mid-trace replica loss under heavy load: replica 1 dies when half
/// the trace has arrived, the shard map bumps to epoch 1 and the
/// survivors absorb its keys. The offered rate swamps every replica's
/// first-segment capacity, so the dead replica is guaranteed a
/// backlog to drain — `rerouted > 0` — and the report asserts the
/// exact conservation `completed + shed + rerouted == offered`.
pub fn fleet_rebalance() -> FleetScenario {
    FleetScenario {
        base: fog_fleet_base(
            "fleet_rebalance",
            "replica loss mid-trace: epoch bump, survivors absorb, exact conservation",
            TrafficTrace {
                arrival_rate_hz: 240_000.0,
                n_requests: 6_000,
                smoke_n_requests: 600,
                seed: 47,
                arrival: ArrivalProcess::Poisson,
            },
            0,
        ),
        fleet: FleetConfig {
            replicas: 3,
            vnodes: 64,
            hash_seed: 0xF1EE_7004,
            shared_cloud: false,
            keys: KeyDist::Uniform,
            fail: Some(FleetFailure { replica: 1, at_frac: 0.5 }),
        },
    }
}

/// The fleet scenario matrix, in reporting order.
pub fn fleet_all() -> Vec<FleetScenario> {
    vec![fleet_fog(), fleet_diurnal(), fleet_hotkey(), fleet_rebalance()]
}

/// Per-fleet-preset outcome: the search half of [`ScenarioReport`]
/// plus fleet-level serving accounting. Everything except the
/// `"timing"` block is bit-reproducible across runs, hosts, worker
/// counts and replica-iteration order.
#[derive(Debug, Clone)]
pub struct FleetScenarioReport {
    pub scenario: String,
    pub platform: String,
    pub model: String,
    /// Search worker threads (input parameter; excluded from
    /// [`Self::deterministic_json`] alongside the timings).
    pub workers: usize,
    pub replicas: usize,
    pub vnodes: usize,
    pub shared_cloud: bool,
    pub n_requests: usize,
    pub arrival_rate_hz: f64,
    // --- search outcome (shared by all replicas) -------------------------
    pub exits: Vec<usize>,
    pub assignment: Vec<usize>,
    pub thresholds: Vec<f64>,
    pub score: f64,
    // --- fleet serving outcome -------------------------------------------
    pub completed: usize,
    pub shed: usize,
    pub shed_queue: usize,
    pub shed_deadline: usize,
    pub shed_bucket: usize,
    /// Requests dropped-and-redirected out of the modeled fleet when
    /// their replica died (`completed + shed + rerouted ==
    /// n_requests`, exactly).
    pub rerouted: usize,
    /// Final shard-map epoch (= rebalances fired).
    pub epoch: u64,
    pub offered_per_replica: Vec<usize>,
    pub completed_per_replica: Vec<usize>,
    pub term_hist: Vec<usize>,
    pub accuracy: f64,
    pub mean_energy_mj: f64,
    /// Reserved device time per *base* processor, aggregated over
    /// replicas (plus the shared cloud timeline, when enabled).
    pub proc_busy_s: Vec<f64>,
    pub sim_latency_p50_s: f64,
    pub sim_latency_p99_s: f64,
    /// Largest depth each stage queue reached, replica-major per
    /// global stage (`replica * nseg + seg`).
    pub queue_max_depth: Vec<usize>,
    // --- volatile wall-clock measurements -------------------------------
    pub search_wall_s: f64,
    pub serve_wall_s: f64,
    pub throughput_rps: f64,
}

impl FleetScenarioReport {
    pub fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("scenario".into(), Json::Str(self.scenario.clone()));
        m.insert("platform".into(), Json::Str(self.platform.clone()));
        m.insert("model".into(), Json::Str(self.model.clone()));
        m.insert("workers".into(), Json::Num(self.workers as f64));
        m.insert("replicas".into(), Json::Num(self.replicas as f64));
        m.insert("vnodes".into(), Json::Num(self.vnodes as f64));
        m.insert("shared_cloud".into(), Json::Bool(self.shared_cloud));
        m.insert("n_requests".into(), Json::Num(self.n_requests as f64));
        m.insert("arrival_rate_hz".into(), Json::Num(self.arrival_rate_hz));
        m.insert("exits".into(), uarr(&self.exits));
        m.insert("assignment".into(), uarr(&self.assignment));
        m.insert("thresholds".into(), farr(&self.thresholds));
        m.insert("score".into(), Json::Num(self.score));
        m.insert("completed".into(), Json::Num(self.completed as f64));
        m.insert("shed".into(), Json::Num(self.shed as f64));
        m.insert("shed_queue".into(), Json::Num(self.shed_queue as f64));
        m.insert("shed_deadline".into(), Json::Num(self.shed_deadline as f64));
        m.insert("shed_bucket".into(), Json::Num(self.shed_bucket as f64));
        m.insert("rerouted".into(), Json::Num(self.rerouted as f64));
        m.insert("epoch".into(), Json::Num(self.epoch as f64));
        m.insert("offered_per_replica".into(), uarr(&self.offered_per_replica));
        m.insert("completed_per_replica".into(), uarr(&self.completed_per_replica));
        m.insert("term_hist".into(), uarr(&self.term_hist));
        m.insert("accuracy".into(), Json::Num(self.accuracy));
        m.insert("mean_energy_mj".into(), Json::Num(self.mean_energy_mj));
        m.insert("proc_busy_s".into(), farr(&self.proc_busy_s));
        m.insert("sim_latency_p50_s".into(), Json::Num(self.sim_latency_p50_s));
        m.insert("sim_latency_p99_s".into(), Json::Num(self.sim_latency_p99_s));
        m.insert("queue_max_depth".into(), uarr(&self.queue_max_depth));
        let mut t = BTreeMap::new();
        t.insert("search_wall_s".into(), Json::Num(self.search_wall_s));
        t.insert("serve_wall_s".into(), Json::Num(self.serve_wall_s));
        t.insert("throughput_rps".into(), Json::Num(self.throughput_rps));
        m.insert("timing".into(), Json::Obj(t));
        Json::Obj(m)
    }

    /// [`Self::to_json`] minus the volatile keys (`timing`,
    /// `workers`): the byte-reproducible payload the fleet
    /// determinism CI leg byte-diffs across worker counts.
    pub fn deterministic_json(&self) -> Json {
        let mut j = self.to_json();
        if let Json::Obj(m) = &mut j {
            m.remove("timing");
            m.remove("workers");
        }
        j
    }

    pub fn print(&self) {
        println!(
            "=== {} — {} on {} x{}{} ===",
            self.scenario,
            self.model,
            self.platform,
            self.replicas,
            if self.shared_cloud { " (shared cloud)" } else { "" }
        );
        println!(
            "  search: exits {:?} -> procs {:?} (score {:.4}, {:.2}s)",
            self.exits, self.assignment, self.score, self.search_wall_s
        );
        println!(
            "  fleet: {}/{} completed ({} shed, {} rerouted, epoch {}) at {:.0} req/s",
            self.completed,
            self.n_requests,
            self.shed,
            self.rerouted,
            self.epoch,
            self.arrival_rate_hz
        );
        println!(
            "  per replica: offered {:?} completed {:?}",
            self.offered_per_replica, self.completed_per_replica
        );
        if self.shed > 0 {
            println!(
                "  shed breakdown: {} queue-full / {} deadline / {} bucket",
                self.shed_queue, self.shed_deadline, self.shed_bucket
            );
        }
        println!(
            "  sim latency p50 {:.4}s p99 {:.4}s | acc {:.4} | term hist {:?}",
            self.sim_latency_p50_s, self.sim_latency_p99_s, self.accuracy, self.term_hist
        );
    }
}

/// Run one fleet preset through the closed loop: synthetic bank →
/// search (once — replicas share the solution) → analytic sim →
/// [`serve_fleet_synthetic`] through the fleet executor. Fleet
/// serving is synthetic-backend only: the fleet layer multiplies the
/// *discrete-event* plane, and calibrated-mode compute backends add
/// nothing to it but wall-clock. Every conservation identity is
/// enforced here as a hard failure, not a report field.
pub fn run_fleet_scenario(
    fs: &FleetScenario,
    workers: usize,
    exec_workers: usize,
    smoke: bool,
) -> Result<FleetScenarioReport> {
    let sc = &fs.base;
    let fleet = &fs.fleet;
    fleet.validate()?;
    let bank = build_bank(sc);
    let cfg = FlowConfig {
        latency_constraint_s: sc.latency_constraint_s,
        w_eff: sc.w_eff,
        w_acc: sc.w_acc,
        workers,
        joint: sc.joint,
        ..FlowConfig::default()
    };
    let t0 = Instant::now();
    let out = na::augment_prepared(&bank, &sc.graph, sc.name, &sc.platform, &cfg, None)?;
    let search_wall_s = t0.elapsed().as_secs_f64();
    let sol = &out.solution;

    let mapping = sol.mapping();
    let sim = simulate(&sc.graph, &mapping, &sc.platform);
    let worst_path_s = sim.stages.last().map(|s| s.cum_latency_s).unwrap_or(0.0);
    let qos = sc.resolve_qos(worst_path_s);

    let n_requests = if smoke { sc.traffic.smoke_n_requests } else { sc.traffic.n_requests };
    let scfg = ServeConfig {
        arrival_rate_hz: sc.traffic.arrival_rate_hz,
        n_requests,
        queue_cap: sc.queue_cap,
        batch_max: 1,
        seed: sc.traffic.seed,
        exec_workers,
        qos,
        arrival: sc.traffic.arrival,
    };
    let t0 = Instant::now();
    let fm = serve_fleet_synthetic(&sc.graph, sol, &sc.platform, &scfg, fleet)?;
    let serve_wall_s = t0.elapsed().as_secs_f64();
    let m = &fm.metrics;

    if m.completed + m.shed + fm.rerouted != n_requests {
        bail!(
            "{}: fleet conservation broken ({} completed + {} shed + {} rerouted != {} offered)",
            sc.name,
            m.completed,
            m.shed,
            fm.rerouted,
            n_requests
        );
    }
    if m.shed != m.shed_queue + m.shed_deadline + m.shed_bucket {
        bail!(
            "{}: shed breakdown broken ({} != {} + {} + {})",
            sc.name,
            m.shed,
            m.shed_queue,
            m.shed_deadline,
            m.shed_bucket
        );
    }
    if fm.offered_per_replica.iter().sum::<usize>() != n_requests {
        bail!("{}: per-replica offered counts do not sum to the trace", sc.name);
    }
    if fm.completed_per_replica.iter().sum::<usize>() != m.completed {
        bail!("{}: per-replica completions do not sum to the total", sc.name);
    }
    match fleet.fail {
        None => {
            if fm.rerouted != 0 || fm.epoch != 0 {
                bail!(
                    "{}: no replica failed, yet {} rerouted at epoch {}",
                    sc.name,
                    fm.rerouted,
                    fm.epoch
                );
            }
        }
        Some(f) => {
            if fm.epoch != 1 {
                bail!("{}: one failure must land at epoch 1, got {}", sc.name, fm.epoch);
            }
            if fm.rerouted == 0 {
                bail!("{}: replica {} died with nothing to reroute", sc.name, f.replica);
            }
            // with nothing shed, every request offered to the dead
            // replica either completed there or was rerouted
            if m.shed == 0
                && fm.completed_per_replica[f.replica] + fm.rerouted
                    != fm.offered_per_replica[f.replica]
            {
                bail!(
                    "{}: dead-replica ledger broken ({} completed + {} rerouted != {} offered)",
                    sc.name,
                    fm.completed_per_replica[f.replica],
                    fm.rerouted,
                    fm.offered_per_replica[f.replica]
                );
            }
        }
    }
    if sc.queue_cap == 0 && m.shed_queue != 0 {
        bail!("{}: unbounded queues must not shed on depth ({} shed)", sc.name, m.shed_queue);
    }
    if sc.queue_cap == 0 && !qos.can_shed() && m.shed != 0 {
        bail!("{}: roomy queues without QoS must not shed ({} shed)", sc.name, m.shed);
    }
    if let KeyDist::Hotspot { .. } = fleet.keys {
        let max = fm.offered_per_replica.iter().copied().max().unwrap_or(0);
        let fair = n_requests as f64 / fleet.replicas as f64;
        if (max as f64) < 1.2 * fair {
            bail!(
                "{}: hot-key preset shows no skew (max offered {} vs fair share {:.0})",
                sc.name,
                max,
                fair
            );
        }
    }
    if m.completed == 0 {
        bail!("{}: nothing served (all {} offered requests lost)", sc.name, n_requests);
    }

    Ok(FleetScenarioReport {
        scenario: sc.name.to_string(),
        platform: sc.platform.name.clone(),
        model: sc.graph.model.clone(),
        workers: out.report.workers,
        replicas: fleet.replicas,
        vnodes: fleet.vnodes,
        shared_cloud: fleet.shared_cloud,
        n_requests,
        arrival_rate_hz: sc.traffic.arrival_rate_hz,
        exits: sol.exits.clone(),
        assignment: sol.assignment.clone(),
        thresholds: sol.thresholds.clone(),
        score: sol.score,
        completed: m.completed,
        shed: m.shed,
        shed_queue: m.shed_queue,
        shed_deadline: m.shed_deadline,
        shed_bucket: m.shed_bucket,
        rerouted: fm.rerouted,
        epoch: fm.epoch,
        offered_per_replica: fm.offered_per_replica.clone(),
        completed_per_replica: fm.completed_per_replica.clone(),
        term_hist: m.term_hist.clone(),
        accuracy: m.quality.accuracy,
        mean_energy_mj: m.mean_energy_mj,
        proc_busy_s: m.proc_busy_s.clone(),
        sim_latency_p50_s: m.sim_latency.p50,
        sim_latency_p99_s: m.sim_latency.p99,
        queue_max_depth: m.queue_stats.iter().map(|q| q.max_depth).collect(),
        search_wall_s,
        serve_wall_s,
        throughput_rps: m.throughput_rps,
    })
}

/// Run every fleet preset in [`fleet_all`].
pub fn run_fleet_all(
    workers: usize,
    exec_workers: usize,
    smoke: bool,
) -> Result<Vec<FleetScenarioReport>> {
    fleet_all().iter().map(|fs| run_fleet_scenario(fs, workers, exec_workers, smoke)).collect()
}

/// Aggregate fleet reports into the `BENCH_scenarios_fleet.json`
/// document (same shell as [`bench_json`], `bench` name
/// `scenarios_fleet`). With `deterministic`, entries carry only the
/// byte-reproducible payload — the document the CI determinism leg
/// byte-diffs across worker counts.
pub fn fleet_bench_json(
    reports: &[FleetScenarioReport],
    smoke: bool,
    deterministic: bool,
) -> Json {
    let entries = reports.iter().map(|r| {
        let mut j = if deterministic { r.deterministic_json() } else { r.to_json() };
        if let Json::Obj(m) = &mut j {
            m.remove("workers");
        }
        (r.scenario.clone(), j)
    });
    bench_doc("scenarios_fleet", smoke, entries.collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_are_wellformed() {
        let ps = all();
        assert_eq!(ps.len(), 7);
        let mut names: Vec<&str> = ps.iter().map(|s| s.name).collect();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), 7, "preset names must be unique");
        for sc in &ps {
            sc.platform.validate().unwrap();
            assert!(sc.platform.max_classifiers() >= 2, "{}: needs room for an EE", sc.name);
            assert!(sc.traffic.smoke_n_requests > 0);
            assert!(sc.traffic.smoke_n_requests <= sc.traffic.n_requests);
        }
        // exactly one bounded-queue (shedding) preset in the matrix —
        // the QoS presets shed by admission policy, not queue depth
        let bounded: Vec<&str> =
            ps.iter().filter(|s| s.queue_cap > 0).map(|s| s.name).collect();
        assert_eq!(bounded, vec!["stress_fog_shed"]);
        let qos: Vec<&str> =
            ps.iter().filter(|s| s.qos.enabled()).map(|s| s.name).collect();
        assert_eq!(qos, vec!["multi_tenant_fog", "overload_storm"]);
    }

    #[test]
    fn mesh_preset_is_exhaustively_intractable_but_roomy() {
        use crate::mapping::{MapSearch, MappingObjective};
        let ps = mesh_all();
        assert_eq!(ps.len(), 1);
        let sc = &ps[0];
        assert_eq!(sc.name, "mesh_cifar");
        sc.platform.validate().unwrap();
        assert_eq!(sc.platform.processors.len(), 16);
        // roomy serving: no queue bound, no QoS — the preset must
        // never shed, so the accounting guards in run_scenario_with
        // stay hard assertions
        assert_eq!(sc.queue_cap, 0);
        assert!(!sc.qos.enabled() && sc.deadline_slack == 0.0);
        assert!(sc.traffic.smoke_n_requests > 0);
        assert!(sc.traffic.smoke_n_requests <= sc.traffic.n_requests);
        // the point of the preset: the largest per-subset assignment
        // space (all five EEs taken -> 6 segments over 16 tiles) is
        // far past the exhaustive cap, so Auto resolves to B&B there
        let max_nseg = sc.graph.ee_locations.len() + 1;
        assert_eq!(max_nseg, 6);
        let obj = MappingObjective::default();
        assert!(MappingObjective::space(max_nseg, 16) > obj.auto_threshold);
        assert_eq!(obj.resolved_search(max_nseg, 16), MapSearch::BnB);
        // …while small subsets stay on the bit-frozen exhaustive path
        assert_eq!(obj.resolved_search(3, 16), MapSearch::Exhaustive);
    }

    #[test]
    fn joint_preset_mirrors_mesh_cifar_exactly() {
        let base = mesh_cifar();
        let ps = mesh_joint_all();
        assert_eq!(ps.len(), 1);
        let sc = &ps[0];
        assert_eq!(sc.name, "mesh_cifar_joint");
        assert!(sc.joint, "the joint preset must run the joint search");
        // every search/serving knob mirrors mesh_cifar, so report
        // differences are attributable to the search regime alone
        assert_eq!(sc.bank_seed, base.bank_seed);
        assert_eq!(sc.n_cal, base.n_cal);
        assert_eq!(sc.graph.model, base.graph.model);
        assert_eq!(sc.graph.ee_locations, base.graph.ee_locations);
        assert_eq!(sc.platform.name, base.platform.name);
        assert_eq!(sc.w_eff, base.w_eff);
        assert_eq!(sc.w_acc, base.w_acc);
        assert_eq!(sc.traffic.seed, base.traffic.seed);
        assert_eq!(sc.traffic.n_requests, base.traffic.n_requests);
        assert_eq!(sc.queue_cap, base.queue_cap);
        // the bit-frozen matrices never opt in: their artifacts must
        // keep the exact two-phase key set
        assert!(!base.joint);
        assert!(all().iter().all(|s| !s.joint));
        assert!(fleet_all().iter().all(|f| !f.base.joint));
    }

    #[test]
    fn multi_tenant_preset_throttles_below_the_offered_load() {
        // the guarantee behind `shed_bucket > 0`: even with a 50%
        // slack on the trace duration (the trace spans ~n/rate seconds
        // of virtual time), the aggregate token supply — initial burst
        // capacity plus refill over the slack-padded window — cannot
        // admit the whole smoke trace, let alone the full one. The
        // bucket check runs before every other policy, so this bound
        // holds regardless of the deadline or queue state.
        let sc = multi_tenant_fog();
        assert_eq!(sc.queue_cap, 0, "sheds must come from QoS, not queue depth");
        assert!(sc.qos.tenants > 0 && sc.qos.can_shed());
        let burst_total = sc.qos.tenants as f64 * sc.qos.bucket_burst;
        let refill_total_hz = sc.qos.tenants as f64 * sc.qos.bucket_rate_hz;
        for n in [sc.traffic.smoke_n_requests, sc.traffic.n_requests] {
            let window_s = 1.5 * n as f64 / sc.traffic.arrival_rate_hz;
            let admissible = burst_total + refill_total_hz * window_s;
            assert!(
                admissible < 0.8 * n as f64,
                "token supply ({admissible:.0}) must starve the offered load ({n})"
            );
        }
        // slack-resolved deadline: finite only after resolution
        assert!(sc.qos.deadline_s.is_infinite() && sc.deadline_slack > 0.0);
        let resolved = sc.resolve_qos(0.125);
        assert_eq!(resolved.deadline_s, 0.25);
    }

    #[test]
    fn storm_preset_is_tamed_by_deadline_admission_alone() {
        let sc = overload_storm();
        // only the deadline can shed: queues unbounded, no buckets
        assert_eq!(sc.queue_cap, 0);
        assert_eq!(sc.qos.tenants, 0);
        assert!(sc.qos.deadline_s.is_finite() && sc.deadline_slack == 0.0);
        assert!(matches!(sc.traffic.arrival, ArrivalProcess::Mmpp { .. }));
        let seg0_macs: f64 = sc.graph.blocks[..=1].iter().map(|b| b.macs as f64).sum();
        let d = sc.qos.deadline_s;
        for proc in &sc.platform.processors[..3] {
            let c0 = seg0_macs / proc.macs_per_sec;
            // storm: the calm rate alone swamps every local tier's
            // first-segment service rate (bursts only make it worse)
            assert!(
                sc.traffic.arrival_rate_hz > 2.0 * (1.0 / c0),
                "{}: calm rate must exceed 2x the {} capacity",
                sc.name,
                proc.name
            );
            // …yet an uncontended first request clears the deadline
            // with room for the boundary transfer on every local tier
            assert!(
                2.0 * c0 < d,
                "{}: deadline {d}s too tight for an idle {}",
                sc.name,
                proc.name
            );
        }
        // the admission predictor keeps the admitted count provably
        // below the offered count: per-stage-0 service c0, the queue
        // never predicts past arrival + d, so dispatches fit in
        // (1.5 * trace_span + d + c0) / c0 + 1 — evaluated at the
        // *fastest* local tier (most admissions), with a 50% slack on
        // the trace span, it stays well under the offered trace
        let c0_min = sc.platform.processors[..3]
            .iter()
            .map(|p| seg0_macs / p.macs_per_sec)
            .fold(f64::INFINITY, f64::min);
        for n in [sc.traffic.smoke_n_requests, sc.traffic.n_requests] {
            let span = 1.5 * n as f64 / sc.traffic.arrival_rate_hz;
            let admitted_bound = (span + d + c0_min) / c0_min + 1.0;
            assert!(
                admitted_bound < 0.7 * n as f64,
                "admission bound ({admitted_bound:.0}) must stay below the trace ({n})"
            );
        }
    }

    #[test]
    fn fleet_presets_are_wellformed() {
        let ps = fleet_all();
        assert_eq!(ps.len(), 4);
        let mut names: Vec<&str> = ps.iter().map(|s| s.base.name).collect();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), 4, "fleet preset names must be unique");
        for fs in &ps {
            assert!(fs.base.name.starts_with("fleet_"), "{}: fleet namespace", fs.base.name);
            fs.fleet.validate().unwrap();
            fs.base.platform.validate().unwrap();
            assert!(fs.fleet.replicas > 1, "{}: a fleet preset needs a fleet", fs.base.name);
            assert!(fs.base.traffic.smoke_n_requests > 0);
            assert!(fs.base.traffic.smoke_n_requests <= fs.base.traffic.n_requests);
            assert!(!fs.base.qos.enabled(), "fleet presets shed by queue depth, not QoS");
        }
        let failing: Vec<&str> =
            ps.iter().filter(|s| s.fleet.fail.is_some()).map(|s| s.base.name).collect();
        assert_eq!(failing, vec!["fleet_rebalance"]);
        let skewed: Vec<&str> = ps
            .iter()
            .filter(|s| matches!(s.fleet.keys, KeyDist::Hotspot { .. }))
            .map(|s| s.base.name)
            .collect();
        assert_eq!(skewed, vec!["fleet_hotkey"]);
        let diurnal: Vec<&str> = ps
            .iter()
            .filter(|s| matches!(s.base.traffic.arrival, ArrivalProcess::Diurnal { .. }))
            .map(|s| s.base.name)
            .collect();
        assert_eq!(diurnal, vec!["fleet_diurnal"]);
    }

    #[test]
    fn rebalance_preset_guarantees_a_backlog_at_the_flip() {
        let fs = fleet_rebalance();
        let sc = &fs.base;
        assert_eq!(sc.queue_cap, 0, "conservation must come from rerouting, not shedding");
        assert!(!sc.qos.can_shed());
        let f = fs.fleet.fail.expect("rebalance preset fails a replica");
        assert!(f.at_frac > 0.0 && f.at_frac < 1.0, "the loss must land mid-trace");
        // the offered rate swamps the *fleet-aggregate* first-segment
        // capacity of every local tier with a wide margin, so whatever
        // key shares the hash seed deals, the dying replica has queued
        // or in-flight work to reroute when the flip fires
        let seg0_macs: f64 = sc.graph.blocks[..=1].iter().map(|b| b.macs as f64).sum();
        for proc in &sc.platform.processors[..3] {
            let service_hz = proc.macs_per_sec / seg0_macs;
            assert!(
                sc.traffic.arrival_rate_hz > 4.0 * fs.fleet.replicas as f64 * service_hz,
                "{}: {} req/s must swamp {} x{} ({:.0} req/s aggregate)",
                sc.name,
                sc.traffic.arrival_rate_hz,
                proc.name,
                fs.fleet.replicas,
                fs.fleet.replicas as f64 * service_hz
            );
        }
    }

    #[test]
    fn easy_profile_clears_the_grid() {
        let mut rng = Rng::seeded(5);
        let p = easy_profile(&mut rng, 500, 0.98);
        let grid = na::threshold_grid(5);
        let top = grid[grid.len() - 1];
        assert!(p.conf.iter().all(|&c| (c as f64) > top), "every sample above {top}");
        let (term, _) = p.marginals(top);
        assert_eq!(term, 1.0);
        assert!(p.accuracy() > 0.9);
    }

    #[test]
    fn bank_is_deterministic() {
        let sc = kws_psoc6();
        let a = build_bank(&sc);
        let b = build_bank(&sc);
        assert_eq!(a.exits.len(), b.exits.len());
        for (loc, ex) in &a.exits {
            assert_eq!(ex.w, b.exits[loc].w, "head weights at {loc}");
        }
        for (loc, p) in &a.profiles {
            assert_eq!(p.conf, b.profiles[loc].conf, "profile at {loc}");
        }
    }

    #[test]
    fn shed_preset_is_overloaded_on_every_local_tier() {
        // the guarantee behind the deterministic-shed claim: the
        // offered rate exceeds the first segment's service rate on
        // every tier a sane mapping would place it on (everything but
        // the cloud GPU, which the WAN hop prices out of seg-0)
        let sc = stress_fog_shed();
        assert!(sc.queue_cap > 0, "bounded queues");
        let seg0_macs: f64 = sc.graph.blocks[..=1].iter().map(|b| b.macs as f64).sum();
        for proc in &sc.platform.processors[..3] {
            let service_hz = proc.macs_per_sec / seg0_macs;
            assert!(
                sc.traffic.arrival_rate_hz > 1.5 * service_hz,
                "{}: {} req/s must swamp {} ({:.0} req/s capacity)",
                sc.name,
                sc.traffic.arrival_rate_hz,
                proc.name,
                service_hz
            );
        }
    }
}
