//! Dataset loading: raw little-endian blobs written by python aot.py
//! (x: f32 row-major, y: i32), indexed by the manifest.

use anyhow::{anyhow, Context, Result};

use crate::runtime::{Manifest, ModelInfo};

#[derive(Debug, Clone)]
pub struct Split {
    /// (n, feature...) flattened row-major.
    pub x: Vec<f32>,
    pub y: Vec<i32>,
    pub n: usize,
    /// per-sample feature element count
    pub feat: usize,
}

impl Split {
    pub fn sample(&self, i: usize) -> &[f32] {
        &self.x[i * self.feat..(i + 1) * self.feat]
    }
}

pub fn load_split(man: &Manifest, model: &ModelInfo, split: &str) -> Result<Split> {
    let info = model
        .data
        .get(split)
        .ok_or_else(|| anyhow!("model {} has no split {split:?}", model.name))?;
    let xp = man.path(&info.x);
    let yp = man.path(&info.y);
    let xb = std::fs::read(&xp).with_context(|| format!("read {}", xp.display()))?;
    let yb = std::fs::read(&yp).with_context(|| format!("read {}", yp.display()))?;

    let feat: usize = model.input_shape.iter().product();
    let expect_x = info.n * feat * 4;
    if xb.len() != expect_x {
        return Err(anyhow!(
            "{split} x: expected {expect_x} bytes (n={} feat={feat}), got {}",
            info.n,
            xb.len()
        ));
    }
    if yb.len() != info.n * 4 {
        return Err(anyhow!("{split} y: expected {} bytes, got {}", info.n * 4, yb.len()));
    }

    let x = xb
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect();
    let y = yb
        .chunks_exact(4)
        .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect();
    Ok(Split { x, y, n: info.n, feat })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sample_slicing() {
        let s = Split {
            x: (0..12).map(|i| i as f32).collect(),
            y: vec![0, 1, 2],
            n: 3,
            feat: 4,
        };
        assert_eq!(s.sample(1), &[4.0, 5.0, 6.0, 7.0]);
    }
}
