//! Summary statistics used by the benches, the simulator and the
//! serving-metrics pipeline.

#[derive(Debug, Clone, Default)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
    pub p50: f64,
    pub p90: f64,
    pub p99: f64,
}

/// Nearest-rank percentile over an already-sorted slice.
///
/// The convention the exact-gated CI baselines depend on: the index is
/// `round(p/100 · (n − 1))` with ties rounded half away from zero (so
/// `n = 2, p = 50` picks the *upper* element), and the returned value
/// is always an element of the input — never an interpolation. `p = 0`
/// returns the minimum, `p = 100` the maximum, and an empty slice
/// returns NaN. Callers sort with `total_cmp`, which places NaN after
/// every finite value, so NaN inputs surface in the top percentiles
/// instead of poisoning the whole summary.
pub fn percentile(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return f64::NAN;
    }
    let idx = (p / 100.0 * (sorted.len() - 1) as f64).round() as usize;
    sorted[idx.min(sorted.len() - 1)]
}

/// Moments plus nearest-rank percentiles (see [`percentile`] for the
/// exact convention). Sorting uses `total_cmp`, so NaN inputs land at
/// the top of the order: `max` (and high percentiles) become NaN while
/// `min` and the low percentiles stay finite.
pub fn summarize(xs: &[f64]) -> Summary {
    if xs.is_empty() {
        return Summary::default();
    }
    let n = xs.len();
    let mean = xs.iter().sum::<f64>() / n as f64;
    let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
    let mut sorted = xs.to_vec();
    sorted.sort_by(|a, b| a.total_cmp(b));
    Summary {
        n,
        mean,
        std: var.sqrt(),
        min: sorted[0],
        max: sorted[n - 1],
        p50: percentile(&sorted, 50.0),
        p90: percentile(&sorted, 90.0),
        p99: percentile(&sorted, 99.0),
    }
}

/// Format a quantity with engineering suffix (k / M / G).
pub fn eng(x: f64) -> String {
    let ax = x.abs();
    if ax >= 1e9 {
        format!("{:.2}G", x / 1e9)
    } else if ax >= 1e6 {
        format!("{:.2}M", x / 1e6)
    } else if ax >= 1e3 {
        format!("{:.2}k", x / 1e3)
    } else {
        format!("{x:.2}")
    }
}

/// Wall-clock a closure; returns (result, seconds).
pub fn timed<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t0 = std::time::Instant::now();
    let out = f();
    (out, t0.elapsed().as_secs_f64())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic() {
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        let s = summarize(&xs);
        assert_eq!(s.n, 100);
        assert!((s.mean - 50.5).abs() < 1e-9);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 100.0);
        assert!((s.p50 - 50.0).abs() <= 1.0);
        assert!((s.p99 - 99.0).abs() <= 1.0);
    }

    #[test]
    fn empty_is_default() {
        assert_eq!(summarize(&[]).n, 0);
        assert!(percentile(&[], 50.0).is_nan());
    }

    #[test]
    fn single_element_is_every_percentile() {
        let s = summarize(&[4.25]);
        assert_eq!(s.n, 1);
        assert_eq!(s.mean, 4.25);
        assert_eq!(s.std, 0.0);
        for p in [0.0, 50.0, 90.0, 99.0, 100.0] {
            assert_eq!(percentile(&[4.25], p), 4.25, "p={p}");
        }
    }

    #[test]
    fn two_elements_round_half_up_at_the_median() {
        // nearest-rank with round-half-away-from-zero: p=50 on n=2
        // lands on index round(0.5) = 1, the upper element
        let xs = [1.0, 3.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 49.0), 1.0);
        assert_eq!(percentile(&xs, 50.0), 3.0);
        assert_eq!(percentile(&xs, 100.0), 3.0);
        let s = summarize(&xs);
        assert_eq!(s.p50, 3.0);
        assert_eq!((s.min, s.max), (1.0, 3.0));
    }

    #[test]
    fn extreme_percentiles_are_min_and_max() {
        let xs: Vec<f64> = (1..=37).map(|i| i as f64).collect();
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 37.0);
        // out-of-range p is clamped at the top, never out of bounds
        assert_eq!(percentile(&xs, 250.0), 37.0);
    }

    #[test]
    fn nan_inputs_surface_at_the_top_of_the_order() {
        // total_cmp sorts NaN after every finite value: max goes NaN,
        // min and the low percentiles stay finite
        let s = summarize(&[2.0, f64::NAN, 1.0]);
        assert_eq!(s.min, 1.0);
        assert!(s.max.is_nan());
        assert_eq!(s.p50, 2.0);
        assert!(s.p99.is_nan());
    }

    #[test]
    fn eng_format() {
        assert_eq!(eng(1_500_000.0), "1.50M");
        assert_eq!(eng(2_500.0), "2.50k");
        assert_eq!(eng(3.2e9), "3.20G");
    }
}
