//! Deterministic splitmix64/xoshiro-style PRNG for simulation,
//! workload generation and the property-test harness. No external
//! rand crate in the offline vendor set.

#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 2],
}

impl Rng {
    pub fn seeded(seed: u64) -> Self {
        // splitmix64 expansion of the seed
        let mut z = seed.wrapping_add(0x9E3779B97F4A7C15);
        let mut next = || {
            z = z.wrapping_add(0x9E3779B97F4A7C15);
            let mut x = z;
            x = (x ^ (x >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            x = (x ^ (x >> 27)).wrapping_mul(0x94D049BB133111EB);
            x ^ (x >> 31)
        };
        Rng { s: [next(), next().max(1)] }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        // xoroshiro128+
        let s0 = self.s[0];
        let mut s1 = self.s[1];
        let out = s0.wrapping_add(s1);
        s1 ^= s0;
        self.s[0] = s0.rotate_left(55) ^ s1 ^ (s1 << 14);
        self.s[1] = s1.rotate_left(36);
        out
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform in [0, 1) as f32.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in [0, n).
    ///
    /// `f64()` is strictly below 1.0 (its largest value is
    /// (2^53 − 1) / 2^53), so `f64() * n` truncates to at most
    /// `n − 1` for every `n` representable here — no wrap-around
    /// guard is needed and exactly one draw is consumed.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.f64() * n as f64) as usize
    }

    /// Uniform in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-12);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Exponential with rate lambda (inter-arrival times).
    pub fn exp(&mut self, lambda: f64) -> f64 {
        -self.f64().max(1e-12).ln() / lambda
    }

    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            xs.swap(i, self.below(i + 1));
        }
    }

    /// Sample an index from unnormalized weights.
    ///
    /// The weights need not sum to 1. An empty slice is a caller bug
    /// (debug_assert; release builds return 0 instead of underflowing
    /// `w.len() - 1`). A degenerate total — zero, negative, or
    /// non-finite (a NaN weight poisons the sum) — carries no
    /// preference information, so it falls back to a uniform draw over
    /// the indices rather than silently returning index 0. Every path
    /// consumes exactly one draw, keeping downstream streams aligned.
    pub fn weighted(&mut self, w: &[f64]) -> usize {
        debug_assert!(!w.is_empty(), "weighted() needs at least one weight");
        if w.is_empty() {
            return 0;
        }
        let total: f64 = w.iter().sum();
        if !(total > 0.0) || !total.is_finite() {
            return self.below(w.len());
        }
        let mut x = self.f64() * total;
        for (i, &wi) in w.iter().enumerate() {
            x -= wi;
            if x <= 0.0 {
                return i;
            }
        }
        w.len() - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::seeded(42);
        let mut b = Rng::seeded(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn uniform_mean() {
        let mut r = Rng::seeded(7);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::seeded(9);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.03, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::seeded(3);
        for _ in 0..1000 {
            assert!(r.below(7) < 7);
        }
    }

    #[test]
    fn below_extreme_state_stays_in_range() {
        // the adversarial case for the truncation in `below`: a state
        // whose next output is u64::MAX yields the largest f64() value,
        // (2^53 − 1) / 2^53, and the product must still truncate below n
        let mut r = Rng { s: [u64::MAX, 0] };
        let x = r.f64();
        assert_eq!(x, (((1u64 << 53) - 1) as f64) / (1u64 << 53) as f64);
        let mut r = Rng { s: [u64::MAX, 0] };
        assert_eq!(r.below(8), 7);
        for n in [1usize, 2, 3, 1000, 1 << 20] {
            let mut r = Rng { s: [u64::MAX, 0] };
            assert!(r.below(n) < n, "n={n}");
        }
    }

    #[test]
    fn weighted_prefers_heavy_index() {
        let mut r = Rng::seeded(11);
        let mut hits = [0usize; 3];
        for _ in 0..3000 {
            hits[r.weighted(&[0.1, 0.8, 0.1])] += 1;
        }
        assert!(hits[1] > hits[0] + hits[2], "{hits:?}");
    }

    #[test]
    fn weighted_degenerate_totals_fall_back_to_uniform() {
        // all-zero and NaN totals carry no preference: uniform draw,
        // always in range, never pinned to index 0
        let mut r = Rng::seeded(13);
        let mut seen_nonzero = false;
        for _ in 0..200 {
            let i = r.weighted(&[0.0, 0.0, 0.0, 0.0]);
            assert!(i < 4);
            seen_nonzero |= i != 0;
        }
        assert!(seen_nonzero, "all-zero weights must not pin the draw to index 0");
        for _ in 0..200 {
            let i = r.weighted(&[1.0, f64::NAN, 1.0]);
            assert!(i < 3);
        }
        // degenerate paths still consume exactly one draw: streams of
        // equal seeds stay aligned whatever branch fires
        let mut a = Rng::seeded(17);
        let mut b = Rng::seeded(17);
        a.weighted(&[0.0, 0.0]);
        b.weighted(&[0.5, 0.5]);
        assert_eq!(a.next_u64(), b.next_u64());
    }

    #[cfg(debug_assertions)]
    #[test]
    #[should_panic(expected = "at least one weight")]
    fn weighted_empty_panics_in_debug() {
        Rng::seeded(1).weighted(&[]);
    }

    #[test]
    fn shuffle_permutes() {
        let mut r = Rng::seeded(5);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(v, (0..50).collect::<Vec<_>>());
    }
}
