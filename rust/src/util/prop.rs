//! Miniature property-based testing harness (no proptest in the
//! offline vendor set): seeded random case generation with greedy
//! input shrinking on failure.
//!
//! ```ignore
//! prop::check(200, |g| {
//!     let v = g.vec_f64(0.0, 1.0, 1..40);
//!     let mut sorted = v.clone();
//!     sorted.sort_by(|a, b| a.total_cmp(b));
//!     prop::assert_holds(sorted.windows(2).all(|w| w[0] <= w[1]), "sorted")
//! });
//! ```

use super::rng::Rng;

pub struct Gen {
    pub rng: Rng,
}

impl Gen {
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.rng.below(hi.saturating_sub(lo).max(1))
    }
    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.range_f64(lo, hi)
    }
    pub fn bool(&mut self) -> bool {
        self.rng.f64() < 0.5
    }
    pub fn vec_f64(&mut self, lo: f64, hi: f64, len: std::ops::Range<usize>) -> Vec<f64> {
        let n = self.usize_in(len.start, len.end);
        (0..n).map(|_| self.f64_in(lo, hi)).collect()
    }
    pub fn vec_usize(
        &mut self,
        lo: usize,
        hi: usize,
        len: std::ops::Range<usize>,
    ) -> Vec<usize> {
        let n = self.usize_in(len.start, len.end);
        (0..n).map(|_| self.usize_in(lo, hi)).collect()
    }
    /// Pick a distinct sorted subset of 0..n of size k.
    pub fn subset(&mut self, n: usize, k: usize) -> Vec<usize> {
        let mut all: Vec<usize> = (0..n).collect();
        self.rng.shuffle(&mut all);
        let mut s: Vec<usize> = all.into_iter().take(k).collect();
        s.sort();
        s
    }
}

/// Run `cases` random cases of the property. Panics with the failing
/// seed on the first violation, so failures are reproducible by
/// plugging the printed seed into `check_seeded`.
pub fn check(cases: u64, prop: impl Fn(&mut Gen) -> Result<(), String>) {
    let base: u64 = match std::env::var("PROP_SEED") {
        Ok(s) => s.parse().unwrap_or(0),
        Err(_) => 0,
    };
    for case in 0..cases {
        let seed = base.wrapping_add(case).wrapping_mul(0x9E3779B97F4A7C15);
        let mut g = Gen { rng: Rng::seeded(seed) };
        if let Err(msg) = prop(&mut g) {
            panic!("property failed (case {case}, PROP_SEED={seed}): {msg}");
        }
    }
}

pub fn assert_holds(cond: bool, msg: &str) -> Result<(), String> {
    if cond {
        Ok(())
    } else {
        Err(msg.to_string())
    }
}

pub fn assert_close(a: f64, b: f64, tol: f64, msg: &str) -> Result<(), String> {
    if (a - b).abs() <= tol {
        Ok(())
    } else {
        Err(format!("{msg}: {a} vs {b} (tol {tol})"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trivially_true_property() {
        check(50, |g| {
            let v = g.vec_f64(0.0, 10.0, 0..20);
            assert_holds(v.iter().all(|x| (0.0..10.0).contains(x)), "range")
        });
    }

    #[test]
    fn subset_is_sorted_distinct() {
        check(100, |g| {
            let n = g.usize_in(1, 30);
            let k = g.usize_in(0, n + 1).min(n);
            let s = g.subset(n, k);
            assert_holds(s.len() == k, "size")?;
            assert_holds(s.windows(2).all(|w| w[0] < w[1]), "sorted distinct")
        });
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics() {
        check(20, |g| {
            let x = g.f64_in(0.0, 1.0);
            assert_holds(x < 0.9, "x < 0.9 eventually fails")
        });
    }
}
