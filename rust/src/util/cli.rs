//! Tiny CLI argument parser (no clap in the offline vendor set).
//!
//! Supports `--key value`, `--key=value`, bare `--flag`, and
//! positional arguments, with typed getters and defaults.
//!
//! Boolean switches are ambiguous in this grammar: `--smoke out.json`
//! could mean "smoke = out.json" or "smoke on, then a positional".
//! [`BOOL_FLAGS`] resolves it — names listed there never consume a
//! following token as their value (use `--flag=value` to force one);
//! every other `--key value` pair keeps working unchanged.

use std::collections::BTreeMap;

/// Flags that are on/off switches across every `repro` subcommand and
/// bench binary. A bare occurrence means `true` and the next token —
/// even a non-flag — stays positional. `--flag=value` still overrides
/// explicitly.
pub const BOOL_FLAGS: &[&str] =
    &["smoke", "verbose", "measured", "no-refine", "priority"];

#[derive(Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    pub flags: BTreeMap<String, String>,
}

impl Args {
    pub fn parse(argv: impl IntoIterator<Item = String>) -> Self {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(rest) = a.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if BOOL_FLAGS.contains(&rest) {
                    out.flags.insert(rest.to_string(), "true".to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    out.flags.insert(rest.to_string(), v);
                } else {
                    out.flags.insert(rest.to_string(), "true".to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    pub fn from_env() -> Self {
        Self::parse(std::env::args().skip(1))
    }

    pub fn str(&self, key: &str, default: &str) -> String {
        self.flags.get(key).cloned().unwrap_or_else(|| default.to_string())
    }

    pub fn opt(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn usize(&self, key: &str, default: usize) -> usize {
        self.flags
            .get(key)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    pub fn f64(&self, key: &str, default: f64) -> f64 {
        self.flags
            .get(key)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    pub fn bool(&self, key: &str) -> bool {
        matches!(self.flags.get(key).map(|s| s.as_str()), Some("true" | "1" | "yes"))
    }

    /// Comma-separated usize list, e.g. `--threads 1,2,4`. Falls back
    /// to `default` when the flag is missing or **any** entry fails to
    /// parse (all-or-nothing, so a typo cannot silently drop entries).
    pub fn usize_list(&self, key: &str, default: &[usize]) -> Vec<usize> {
        match self.flags.get(key) {
            Some(v) => {
                let parts: Vec<&str> = v
                    .split(',')
                    .map(str::trim)
                    .filter(|s| !s.is_empty())
                    .collect();
                let parsed: Vec<usize> =
                    parts.iter().filter_map(|s| s.parse().ok()).collect();
                if parts.is_empty() || parsed.len() != parts.len() {
                    default.to_vec()
                } else {
                    parsed
                }
            }
            None => default.to_vec(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn mixed_forms() {
        let a = parse("search --model dscnn --lambda=0.9 --verbose --n 5 out.json");
        assert_eq!(a.positional, vec!["search", "out.json"]);
        assert_eq!(a.str("model", ""), "dscnn");
        assert_eq!(a.f64("lambda", 0.0), 0.9);
        assert!(a.bool("verbose"));
        assert_eq!(a.usize("n", 0), 5);
    }

    #[test]
    fn defaults() {
        let a = parse("cmd");
        assert_eq!(a.str("missing", "dflt"), "dflt");
        assert_eq!(a.usize("missing", 7), 7);
        assert!(!a.bool("missing"));
    }

    #[test]
    fn usize_lists() {
        let a = parse("bench --threads 1,2,4 --bad x,y --typo 1,2x,4");
        assert_eq!(a.usize_list("threads", &[8]), vec![1, 2, 4]);
        assert_eq!(a.usize_list("missing", &[8, 16]), vec![8, 16]);
        assert_eq!(a.usize_list("bad", &[3]), vec![3]);
        // one bad entry rejects the whole list, never a silent subset
        assert_eq!(a.usize_list("typo", &[7]), vec![7]);
    }

    #[test]
    fn flag_before_positional() {
        // an unknown bare flag followed by a positional consumes it as
        // a value; `--flag` followed by another --flag stays boolean
        let a = parse("--x --y val pos");
        assert!(a.bool("x"));
        assert_eq!(a.str("y", ""), "val");
        assert_eq!(a.positional, vec!["pos"]);
    }

    #[test]
    fn known_boolean_flags_never_eat_positionals() {
        // the `repro scenarios --smoke out.json` footgun: --smoke is a
        // switch, so the trailing path must stay positional and the
        // flag must read as true (it used to become smoke="out.json")
        let a = parse("scenarios --smoke out.json");
        assert!(a.bool("smoke"));
        assert_eq!(a.positional, vec!["scenarios", "out.json"]);

        let a = parse("augment --verbose sol.json --no-refine x");
        assert!(a.bool("verbose"));
        assert!(a.bool("no-refine"));
        assert_eq!(a.positional, vec!["augment", "sol.json", "x"]);

        // --measured and --priority are switches too
        let a = parse("serve --measured --priority 7");
        assert!(a.bool("measured"));
        assert!(a.bool("priority"));
        assert_eq!(a.positional, vec!["serve", "7"]);
    }

    #[test]
    fn bool_flag_equals_form_still_overrides() {
        // the escape hatch: an explicit `=` assigns even a known switch
        let a = parse("scenarios --smoke=false out.json");
        assert!(!a.bool("smoke"));
        assert_eq!(a.str("smoke", ""), "false");
        assert_eq!(a.positional, vec!["scenarios", "out.json"]);
    }

    #[test]
    fn value_flags_still_take_the_next_token() {
        // the fix must not break ordinary `--key value` pairs
        let a = parse("scenarios --only stress_fog --out BENCH.json --smoke");
        assert_eq!(a.str("only", ""), "stress_fog");
        assert_eq!(a.str("out", ""), "BENCH.json");
        assert!(a.bool("smoke"));
        assert_eq!(a.positional, vec!["scenarios"]);
    }
}
