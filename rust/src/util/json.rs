//! Minimal JSON parser/writer (RFC 8259 subset sufficient for the
//! artifact manifest: objects, arrays, strings, numbers, booleans,
//! null; no \u surrogate pairs beyond the BMP).

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct ParseError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for ParseError {}

impl Json {
    pub fn parse(s: &str) -> Result<Json, ParseError> {
        let mut p = Parser { b: s.as_bytes(), pos: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.pos != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    // --- typed accessors -------------------------------------------------
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }
    pub fn req(&self, key: &str) -> anyhow::Result<&Json> {
        self.get(key)
            .ok_or_else(|| anyhow::anyhow!("missing key {key:?}"))
    }
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }
    pub fn usize_arr(&self) -> Option<Vec<usize>> {
        self.as_arr()
            .map(|a| a.iter().filter_map(|v| v.as_usize()).collect())
    }
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError { pos: self.pos, msg: msg.into() }
    }
    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }
    fn ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }
    fn eat(&mut self, c: u8) -> Result<(), ParseError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected {:?}", c as char)))
        }
    }
    fn lit(&mut self, s: &str, v: Json) -> Result<Json, ParseError> {
        if self.b[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected {s}")))
        }
    }

    fn value(&mut self) -> Result<Json, ParseError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn object(&mut self) -> Result<Json, ParseError> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected , or }")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, ParseError> {
        self.eat(b'[')?;
        let mut a = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            self.ws();
            a.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(a));
                }
                _ => return Err(self.err("expected , or ]")),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.pos + 1..self.pos + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let start = self.pos;
                    self.pos += 1;
                    while self.pos < self.b.len() && (self.b[self.pos] & 0xC0) == 0x80 {
                        self.pos += 1;
                    }
                    s.push_str(
                        std::str::from_utf8(&self.b[start..self.pos])
                            .map_err(|_| self.err("invalid utf8"))?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        std::str::from_utf8(&self.b[start..self.pos])
            .ok()
            .and_then(|s| s.parse().ok())
            .map(Json::Num)
            .ok_or_else(|| self.err("bad number"))
    }
}

/// Escape + write a JSON string.
fn write_str(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\t' => write!(f, "\\t")?,
            '\r' => write!(f, "\\r")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => write_str(f, s),
            Json::Arr(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_str(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let src = r#"{"a": [1, 2.5, -3e2], "b": {"c": "x\ny"}, "d": true, "e": null}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.req("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.get("d"), Some(&Json::Bool(true)));
        let re = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, re);
    }

    #[test]
    fn rejects_trailing() {
        assert!(Json::parse("{} x").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("\"abc").is_err());
    }

    #[test]
    fn nested_deep() {
        let v = Json::parse(r#"[[[[[1]]]]]"#).unwrap();
        let mut cur = &v;
        for _ in 0..5 {
            cur = &cur.as_arr().unwrap()[0];
        }
        assert_eq!(cur.as_f64(), Some(1.0));
    }

    #[test]
    fn unicode_escape() {
        let v = Json::parse(r#""Aé""#).unwrap();
        assert_eq!(v.as_str(), Some("Aé"));
    }
}
