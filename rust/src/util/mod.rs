//! Hand-rolled substrates.
//!
//! The build environment is fully offline and its vendored crate set
//! has no serde / tokio / clap / criterion / proptest, so the support
//! machinery a framework normally pulls in is implemented here as
//! first-class, tested modules.

pub mod cli;
pub mod json;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod threadpool;
