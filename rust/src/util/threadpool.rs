//! Small fixed-size thread pool (no tokio/rayon in the offline vendor
//! set). Used by the coordinator's device workers and by the parallel
//! sections of the search engine (exit training fan-out, architecture
//! scoring shards, mapping co-search).
//!
//! Panic policy: a panicking job never poisons the pool. Worker
//! threads contain job panics and keep serving the queue; [`ThreadPool::map`]
//! collects every job's outcome and — only after all jobs have
//! finished — re-raises the panic of the lowest-indexed failing item,
//! so panic propagation is deterministic and the pool stays usable.

use std::any::Any;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

pub struct ThreadPool {
    tx: Option<mpsc::Sender<Job>>,
    workers: Vec<thread::JoinHandle<()>>,
    size: usize,
}

impl ThreadPool {
    pub fn new(size: usize) -> Self {
        let size = size.max(1);
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let workers = (0..size)
            .map(|i| {
                let rx = Arc::clone(&rx);
                thread::Builder::new()
                    .name(format!("pool-{i}"))
                    .spawn(move || loop {
                        let job = rx.lock().unwrap().recv();
                        match job {
                            // Contain job panics: the worker survives
                            // and `map` re-raises on the calling side.
                            Ok(job) => {
                                let _ = catch_unwind(AssertUnwindSafe(job));
                            }
                            Err(_) => break,
                        }
                    })
                    .expect("spawn pool worker")
            })
            .collect();
        ThreadPool { tx: Some(tx), workers, size }
    }

    /// Number of worker threads.
    pub fn size(&self) -> usize {
        self.size
    }

    pub fn execute(&self, job: impl FnOnce() + Send + 'static) {
        self.tx
            .as_ref()
            .expect("pool shut down")
            .send(Box::new(job))
            .expect("pool receiver gone");
    }

    /// Map `f` over items in parallel, preserving input order.
    ///
    /// Every job runs to completion before this returns. If any job
    /// panicked, the panic payload of the **lowest item index** is
    /// re-raised here (deterministic regardless of thread timing); the
    /// pool itself remains fully usable afterwards.
    pub fn map<T, R>(&self, items: Vec<T>, f: impl Fn(T) -> R + Send + Sync + 'static) -> Vec<R>
    where
        T: Send + 'static,
        R: Send + 'static,
    {
        let f = Arc::new(f);
        let (tx, rx) = mpsc::channel::<(usize, thread::Result<R>)>();
        let n = items.len();
        for (i, item) in items.into_iter().enumerate() {
            let f = Arc::clone(&f);
            let tx = tx.clone();
            self.execute(move || {
                let r = catch_unwind(AssertUnwindSafe(|| f(item)));
                let _ = tx.send((i, r));
            });
        }
        drop(tx);
        let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
        let mut first_panic: Option<(usize, Box<dyn Any + Send>)> = None;
        for (i, r) in rx {
            match r {
                Ok(v) => out[i] = Some(v),
                Err(p) => {
                    if first_panic.as_ref().map(|(pi, _)| i < *pi).unwrap_or(true) {
                        first_panic = Some((i, p));
                    }
                }
            }
        }
        if let Some((_, p)) = first_panic {
            resume_unwind(p);
        }
        out.into_iter()
            .map(|o| o.expect("pool job dropped without reporting"))
            .collect()
    }
}

/// Run `f` over `items` — on the pool (parallel, order-preserving)
/// when one is given and there is more than one item, inline on the
/// calling thread otherwise. Both paths execute the **same** closure,
/// so a sequential (`workers = 1`) run can never diverge from the
/// parallel one — the bit-identity guarantee of the search engine
/// rests on every fan-out site going through here.
pub fn map_maybe<T, R>(
    pool: Option<&ThreadPool>,
    items: Vec<T>,
    f: impl Fn(T) -> R + Send + Sync + 'static,
) -> Vec<R>
where
    T: Send + 'static,
    R: Send + 'static,
{
    match pool {
        Some(pool) if items.len() > 1 => pool.map(items, f),
        _ => items.into_iter().map(f).collect(),
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.tx.take());
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn zero_size_clamps_to_one_worker() {
        // a zero worker count (failed available_parallelism probe, or
        // `--workers 0`) must yield a working single-worker pool, not
        // an empty one that deadlocks every job
        let pool = ThreadPool::new(0);
        assert_eq!(pool.size(), 1);
        let out = pool.map((0..10).collect(), |x: usize| x + 1);
        assert_eq!(out, (1..=10).collect::<Vec<_>>());
    }

    #[test]
    fn runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        drop(pool); // join
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn map_preserves_order() {
        let pool = ThreadPool::new(3);
        let out = pool.map((0..50).collect(), |x: usize| x * x);
        assert_eq!(out, (0..50).map(|x| x * x).collect::<Vec<_>>());
    }

    #[test]
    fn map_stress_many_more_jobs_than_workers() {
        let pool = ThreadPool::new(3);
        let n = 5000usize;
        let out = pool.map((0..n).collect(), |x: usize| x.wrapping_mul(2654435761));
        assert_eq!(out.len(), n);
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, i.wrapping_mul(2654435761), "order broken at {i}");
        }
    }

    #[test]
    fn panicking_job_propagates_without_hanging_or_poisoning() {
        let pool = ThreadPool::new(2);
        let r = catch_unwind(AssertUnwindSafe(|| {
            pool.map(vec![0usize, 1, 2, 3], |x| {
                if x == 1 {
                    panic!("job boom");
                }
                x * 10
            })
        }));
        let payload = r.expect_err("map must re-raise the job panic");
        let msg = payload
            .downcast_ref::<&str>()
            .copied()
            .unwrap_or("<non-str payload>");
        assert!(msg.contains("job boom"), "unexpected payload: {msg}");
        // the pool survives: a fresh map on the same pool still works
        let out = pool.map((0..100).collect(), |x: usize| x + 1);
        assert_eq!(out, (1..=100).collect::<Vec<_>>());
    }

    #[test]
    fn panic_propagation_is_deterministic_lowest_index() {
        let pool = ThreadPool::new(4);
        for _ in 0..10 {
            let r = catch_unwind(AssertUnwindSafe(|| {
                pool.map((0..64).collect::<Vec<usize>>(), |x| {
                    if x % 7 == 3 {
                        panic!("boom at {x}");
                    }
                    x
                })
            }));
            let payload = r.expect_err("must panic");
            let msg = payload
                .downcast_ref::<String>()
                .cloned()
                .unwrap_or_default();
            // lowest panicking index is 3, regardless of scheduling
            assert_eq!(msg, "boom at 3");
        }
    }

    #[test]
    fn map_maybe_matches_with_and_without_pool() {
        let items: Vec<usize> = (0..200).collect();
        let seq = map_maybe(None, items.clone(), |x| x * 3 + 1);
        let pool = ThreadPool::new(4);
        let par = map_maybe(Some(&pool), items, |x| x * 3 + 1);
        assert_eq!(seq, par);
        // degenerate sizes take the inline path but still work
        assert_eq!(map_maybe(Some(&pool), vec![7usize], |x| x + 1), vec![8]);
        let empty = map_maybe(Some(&pool), Vec::new(), |x: usize| x);
        assert!(empty.is_empty());
    }

    #[test]
    fn execute_panic_does_not_kill_workers() {
        let pool = ThreadPool::new(1);
        pool.execute(|| panic!("fire-and-forget boom"));
        // the single worker must survive to run the next 50 jobs
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..50 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        drop(pool);
        assert_eq!(counter.load(Ordering::SeqCst), 50);
    }
}
