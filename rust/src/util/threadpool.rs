//! Small fixed-size thread pool (no tokio/rayon in the offline vendor
//! set). Used by the serving executor's exec plane and by the parallel
//! sections of the search engine (exit training fan-out, architecture
//! scoring shards, mapping co-search).
//!
//! Two submission styles:
//!
//! * [`ThreadPool::map`] — one-shot fork/join over a `Vec` with an
//!   order-preserving reduction (the search engine's fan-outs);
//! * [`Lanes`] — a reusable handle/ticket API for long-lived stateful
//!   workers: each lane owns a piece of mutable state (a serving-stage
//!   backend, say) and executes its jobs strictly in submission order,
//!   while different lanes run concurrently. Every submission carries
//!   a caller-chosen ticket; [`Lanes::join`] blocks until that
//!   ticket's result (or panic payload) is posted. This is the exec
//!   plane of the coordinator's two-plane discrete-event scheduler.
//!
//! Panic policy: a panicking job never poisons the pool. Worker
//! threads contain job panics and keep serving the queue; [`ThreadPool::map`]
//! collects every job's outcome and — only after all jobs have
//! finished — re-raises the panic of the lowest-indexed failing item,
//! so panic propagation is deterministic and the pool stays usable.
//! [`Lanes`] likewise catches per-job panics, posts the payload under
//! the job's ticket, and keeps draining the lane.

use std::any::Any;
use std::collections::{HashMap, VecDeque};
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::mpsc;
use std::sync::{Arc, Condvar, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

pub struct ThreadPool {
    tx: Option<mpsc::Sender<Job>>,
    workers: Vec<thread::JoinHandle<()>>,
    size: usize,
}

impl ThreadPool {
    pub fn new(size: usize) -> Self {
        let size = size.max(1);
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let workers = (0..size)
            .map(|i| {
                let rx = Arc::clone(&rx);
                thread::Builder::new()
                    .name(format!("pool-{i}"))
                    .spawn(move || loop {
                        let job = rx.lock().unwrap().recv();
                        match job {
                            // Contain job panics: the worker survives
                            // and `map` re-raises on the calling side.
                            Ok(job) => {
                                let _ = catch_unwind(AssertUnwindSafe(job));
                            }
                            Err(_) => break,
                        }
                    })
                    .expect("spawn pool worker")
            })
            .collect();
        ThreadPool { tx: Some(tx), workers, size }
    }

    /// Number of worker threads.
    pub fn size(&self) -> usize {
        self.size
    }

    pub fn execute(&self, job: impl FnOnce() + Send + 'static) {
        self.tx
            .as_ref()
            .expect("pool shut down")
            .send(Box::new(job))
            .expect("pool receiver gone");
    }

    /// Map `f` over items in parallel, preserving input order.
    ///
    /// Every job runs to completion before this returns. If any job
    /// panicked, the panic payload of the **lowest item index** is
    /// re-raised here (deterministic regardless of thread timing); the
    /// pool itself remains fully usable afterwards.
    pub fn map<T, R>(&self, items: Vec<T>, f: impl Fn(T) -> R + Send + Sync + 'static) -> Vec<R>
    where
        T: Send + 'static,
        R: Send + 'static,
    {
        let f = Arc::new(f);
        let (tx, rx) = mpsc::channel::<(usize, thread::Result<R>)>();
        let n = items.len();
        for (i, item) in items.into_iter().enumerate() {
            let f = Arc::clone(&f);
            let tx = tx.clone();
            self.execute(move || {
                let r = catch_unwind(AssertUnwindSafe(|| f(item)));
                let _ = tx.send((i, r));
            });
        }
        drop(tx);
        let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
        let mut first_panic: Option<(usize, Box<dyn Any + Send>)> = None;
        for (i, r) in rx {
            match r {
                Ok(v) => out[i] = Some(v),
                Err(p) => {
                    if first_panic.as_ref().map(|(pi, _)| i < *pi).unwrap_or(true) {
                        first_panic = Some((i, p));
                    }
                }
            }
        }
        if let Some((_, p)) = first_panic {
            resume_unwind(p);
        }
        out.into_iter()
            .map(|o| o.expect("pool job dropped without reporting"))
            .collect()
    }
}

/// Run `f` over `items` — on the pool (parallel, order-preserving)
/// when one is given and there is more than one item, inline on the
/// calling thread otherwise. Both paths execute the **same** closure,
/// so a sequential (`workers = 1`) run can never diverge from the
/// parallel one — the bit-identity guarantee of the search engine
/// rests on every fan-out site going through here.
pub fn map_maybe<T, R>(
    pool: Option<&ThreadPool>,
    items: Vec<T>,
    f: impl Fn(T) -> R + Send + Sync + 'static,
) -> Vec<R>
where
    T: Send + 'static,
    R: Send + 'static,
{
    match pool {
        Some(pool) if items.len() > 1 => pool.map(items, f),
        _ => items.into_iter().map(f).collect(),
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.tx.take());
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

// ---------------------------------------------------------------------------
// Lanes: ordered stateful execution with completion tickets
// ---------------------------------------------------------------------------

type LaneJob<S, R> = Box<dyn FnOnce(&mut S) -> R + Send>;

struct LaneQueue<S, R> {
    /// The lane's exclusive state; `None` while a drainer holds it.
    state: Option<S>,
    pending: VecDeque<(u64, LaneJob<S, R>)>,
    /// Is a drainer currently scheduled/running for this lane?
    active: bool,
}

struct Lane<S, R> {
    q: Mutex<LaneQueue<S, R>>,
}

struct Board<R> {
    done: Mutex<HashMap<u64, thread::Result<R>>>,
    cv: Condvar,
}

impl<R> Board<R> {
    fn post(&self, ticket: u64, r: thread::Result<R>) {
        let mut done = self.done.lock().unwrap();
        let prev = done.insert(ticket, r);
        debug_assert!(prev.is_none(), "ticket {ticket} posted twice");
        self.cv.notify_all();
    }
}

/// Ordered execution lanes with completion tickets on top of
/// [`ThreadPool`].
///
/// Each lane owns one mutable state value `S` (e.g. a serving-stage
/// backend with its RNG). Jobs submitted to a lane run **strictly in
/// submission order** — the determinism anchor for stateful backends —
/// while different lanes execute concurrently on the pool's workers.
/// A lane drains through an actor-style job: the first submission to
/// an idle lane schedules one pool job that pops the lane's queue
/// until empty, so a busy lane never blocks a pool worker on another
/// lane's progress.
///
/// Every submission carries a caller-chosen ticket (unique across the
/// `Lanes` instance); [`Lanes::join`] blocks until that ticket's
/// result is posted and returns it — `Err` carries the panic payload
/// of a job that panicked, the lane itself keeps draining and the
/// pool stays fully usable (the caller decides when and how to
/// re-raise, which is what makes panic propagation deterministic).
pub struct Lanes<S, R> {
    lanes: Vec<Arc<Lane<S, R>>>,
    board: Arc<Board<R>>,
}

impl<S: Send + 'static, R: Send + 'static> Lanes<S, R> {
    /// One lane per entry of `states`.
    pub fn new(states: Vec<S>) -> Self {
        let lanes = states
            .into_iter()
            .map(|s| {
                Arc::new(Lane {
                    q: Mutex::new(LaneQueue {
                        state: Some(s),
                        pending: VecDeque::new(),
                        active: false,
                    }),
                })
            })
            .collect();
        Lanes {
            lanes,
            board: Arc::new(Board { done: Mutex::new(HashMap::new()), cv: Condvar::new() }),
        }
    }

    pub fn n_lanes(&self) -> usize {
        self.lanes.len()
    }

    /// Queue `job` on `lane`; it will run after every job submitted to
    /// that lane before it, with exclusive access to the lane's state.
    /// The result (or panic payload) is posted under `ticket`.
    pub fn submit(
        &self,
        pool: &ThreadPool,
        lane: usize,
        ticket: u64,
        job: impl FnOnce(&mut S) -> R + Send + 'static,
    ) {
        let lane = Arc::clone(&self.lanes[lane]);
        let spawn = {
            let mut q = lane.q.lock().unwrap();
            q.pending.push_back((ticket, Box::new(job)));
            !std::mem::replace(&mut q.active, true)
        };
        if spawn {
            let board = Arc::clone(&self.board);
            pool.execute(move || drain_lane(lane, board));
        }
    }

    /// Block until `ticket`'s job has finished and take its result.
    /// `Err` is the panic payload of a panicking job (the lane and the
    /// pool both survive). Each ticket can be joined exactly once.
    pub fn join(&self, ticket: u64) -> thread::Result<R> {
        let mut done = self.board.done.lock().unwrap();
        loop {
            if let Some(r) = done.remove(&ticket) {
                return r;
            }
            done = self.board.cv.wait(done).unwrap();
        }
    }
}

/// The actor body of one lane: pop-and-run until the queue drains,
/// holding the lane state outside the lock while a job executes so
/// submitters (the event loop) never wait on backend work.
fn drain_lane<S, R>(lane: Arc<Lane<S, R>>, board: Arc<Board<R>>) {
    let mut state = lane
        .q
        .lock()
        .unwrap()
        .state
        .take()
        .expect("lane state present while the lane is marked active");
    loop {
        let next = {
            let mut q = lane.q.lock().unwrap();
            match q.pending.pop_front() {
                Some(x) => x,
                None => {
                    // put the state back and deactivate under the same
                    // lock, so a concurrent submit either sees the lane
                    // active (job queued for this drainer — impossible,
                    // we just saw the queue empty) or spawns a fresh one
                    q.state = Some(state);
                    q.active = false;
                    return;
                }
            }
        };
        let (ticket, job) = next;
        let r = catch_unwind(AssertUnwindSafe(|| job(&mut state)));
        board.post(ticket, r);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn zero_size_clamps_to_one_worker() {
        // a zero worker count (failed available_parallelism probe, or
        // `--workers 0`) must yield a working single-worker pool, not
        // an empty one that deadlocks every job
        let pool = ThreadPool::new(0);
        assert_eq!(pool.size(), 1);
        let out = pool.map((0..10).collect(), |x: usize| x + 1);
        assert_eq!(out, (1..=10).collect::<Vec<_>>());
    }

    #[test]
    fn runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        drop(pool); // join
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn map_preserves_order() {
        let pool = ThreadPool::new(3);
        let out = pool.map((0..50).collect(), |x: usize| x * x);
        assert_eq!(out, (0..50).map(|x| x * x).collect::<Vec<_>>());
    }

    #[test]
    fn map_stress_many_more_jobs_than_workers() {
        let pool = ThreadPool::new(3);
        let n = 5000usize;
        let out = pool.map((0..n).collect(), |x: usize| x.wrapping_mul(2654435761));
        assert_eq!(out.len(), n);
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, i.wrapping_mul(2654435761), "order broken at {i}");
        }
    }

    #[test]
    fn panicking_job_propagates_without_hanging_or_poisoning() {
        let pool = ThreadPool::new(2);
        let r = catch_unwind(AssertUnwindSafe(|| {
            pool.map(vec![0usize, 1, 2, 3], |x| {
                if x == 1 {
                    panic!("job boom");
                }
                x * 10
            })
        }));
        let payload = r.expect_err("map must re-raise the job panic");
        let msg = payload
            .downcast_ref::<&str>()
            .copied()
            .unwrap_or("<non-str payload>");
        assert!(msg.contains("job boom"), "unexpected payload: {msg}");
        // the pool survives: a fresh map on the same pool still works
        let out = pool.map((0..100).collect(), |x: usize| x + 1);
        assert_eq!(out, (1..=100).collect::<Vec<_>>());
    }

    #[test]
    fn panic_propagation_is_deterministic_lowest_index() {
        let pool = ThreadPool::new(4);
        for _ in 0..10 {
            let r = catch_unwind(AssertUnwindSafe(|| {
                pool.map((0..64).collect::<Vec<usize>>(), |x| {
                    if x % 7 == 3 {
                        panic!("boom at {x}");
                    }
                    x
                })
            }));
            let payload = r.expect_err("must panic");
            let msg = payload
                .downcast_ref::<String>()
                .cloned()
                .unwrap_or_default();
            // lowest panicking index is 3, regardless of scheduling
            assert_eq!(msg, "boom at 3");
        }
    }

    #[test]
    fn map_maybe_matches_with_and_without_pool() {
        let items: Vec<usize> = (0..200).collect();
        let seq = map_maybe(None, items.clone(), |x| x * 3 + 1);
        let pool = ThreadPool::new(4);
        let par = map_maybe(Some(&pool), items, |x| x * 3 + 1);
        assert_eq!(seq, par);
        // degenerate sizes take the inline path but still work
        assert_eq!(map_maybe(Some(&pool), vec![7usize], |x| x + 1), vec![8]);
        let empty = map_maybe(Some(&pool), Vec::new(), |x: usize| x);
        assert!(empty.is_empty());
    }

    #[test]
    fn lanes_run_jobs_in_submission_order_per_lane() {
        let pool = ThreadPool::new(4);
        // lane state = the log of job ids the lane has executed
        let lanes: Lanes<Vec<u64>, Vec<u64>> =
            Lanes::new(vec![Vec::new(), Vec::new(), Vec::new()]);
        assert_eq!(lanes.n_lanes(), 3);
        let mut ticket = 0u64;
        for round in 0..50u64 {
            for lane in 0..3 {
                lanes.submit(&pool, lane, ticket, move |log: &mut Vec<u64>| {
                    log.push(round);
                    log.clone()
                });
                ticket += 1;
            }
        }
        // the log observed at round r's job must be exactly 0..=r, for
        // every lane — strict per-lane ordering regardless of worker
        // interleaving
        for t in 0..ticket {
            let round = t / 3;
            let log = lanes.join(t).expect("no panic");
            assert_eq!(log, (0..=round).collect::<Vec<_>>(), "ticket {t}");
        }
    }

    #[test]
    fn lanes_join_works_out_of_order() {
        let pool = ThreadPool::new(2);
        let lanes: Lanes<u64, u64> = Lanes::new(vec![0, 0]);
        for t in 0..10u64 {
            lanes.submit(&pool, (t % 2) as usize, t, move |acc| {
                *acc += t;
                *acc
            });
        }
        // join newest-first: every ticket must still resolve
        for t in (0..10u64).rev() {
            let v = lanes.join(t).expect("no panic");
            assert!(v >= t / 2, "ticket {t} -> {v}");
        }
    }

    #[test]
    fn lanes_contain_panics_and_stay_usable() {
        let pool = ThreadPool::new(2);
        let lanes: Lanes<usize, usize> = Lanes::new(vec![0, 0]);
        lanes.submit(&pool, 0, 0, |n| {
            *n += 1;
            *n
        });
        lanes.submit(&pool, 0, 1, |_| -> usize { panic!("lane boom") });
        // submitted after the panicking job, on the same lane: must
        // still run, with the lane state intact
        lanes.submit(&pool, 0, 2, |n| {
            *n += 1;
            *n
        });
        assert_eq!(lanes.join(0).expect("ok"), 1);
        let payload = lanes.join(1).expect_err("panic payload");
        let msg = payload.downcast_ref::<&str>().copied().unwrap_or("<non-str>");
        assert!(msg.contains("lane boom"), "unexpected payload: {msg}");
        assert_eq!(lanes.join(2).expect("lane survives its panicking job"), 2);
        // and the pool itself is not poisoned
        let out = pool.map((0..20).collect(), |x: usize| x * 2);
        assert_eq!(out, (0..20).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn execute_panic_does_not_kill_workers() {
        let pool = ThreadPool::new(1);
        pool.execute(|| panic!("fire-and-forget boom"));
        // the single worker must survive to run the next 50 jobs
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..50 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        drop(pool);
        assert_eq!(counter.load(Ordering::SeqCst), 50);
    }
}
