//! Native SIMD compute backend: real multiply-accumulate kernels for
//! the serving exec plane (ROADMAP open item 2, resolved).
//!
//! Ports the Python reference kernels
//! (`python/compile/kernels/{conv1d,conv2d,depthwise,dense,ee_head}.py`)
//! to pure Rust, covering exactly the [`crate::graph::fine::Layer`]
//! compute variants — `Conv2d`, `DwConv2d`, `Conv1d`, `Dense` — plus
//! the EE head's GAP → dense → softmax → max-confidence chain.
//!
//! Two implementations sit behind one runtime [`Dispatch`]:
//!
//! * [`scalar`] — the portable, **bit-exact reference**: one fixed
//!   summation order per output element (taps outer, input channels
//!   inner);
//! * [`avx2`] — `f32x8` + FMA lanes over the output-channel axis,
//!   selected via `is_x86_feature_detected!` and forced off with the
//!   env var `RUST_PALLAS_FORCE_SCALAR=1`. Same summation order; FMA
//!   rounding keeps it within 1e-5 relative of scalar (pinned by
//!   `tests/kernel_parity.rs`), and the add-only GAP is bit-exact.
//!
//! [`NativeModel`] assembles seeded-weight layer stacks from a
//! [`crate::graph::BlockGraph`] (ResNet-shaped when the block count
//! matches `3n + 1`, one conv per block otherwise), splits into
//! per-segment stacks for the coordinator's `NativeExec` stage
//! backend, and exposes exact per-block MAC counts that agree with
//! [`crate::graph::fine::FineNode::macs`] on SAME-padded shapes — the
//! cost model the search optimizes is the arithmetic the backend
//! performs.

pub mod avx2;
pub mod scalar;

use crate::graph::BlockGraph;
use crate::util::rng::Rng;

// ---------------------------------------------------------------------------
// layer specs
// ---------------------------------------------------------------------------

/// Shape/behaviour of one NHWC 2-D convolution.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Conv2dSpec {
    pub h: usize,
    pub w: usize,
    pub cin: usize,
    pub cout: usize,
    pub kh: usize,
    pub kw: usize,
    /// (stride_h, stride_w).
    pub stride: (usize, usize),
    /// Symmetric zero padding (pad_h, pad_w).
    pub pad: (usize, usize),
    pub relu: bool,
}

impl Conv2dSpec {
    pub fn out_dims(&self) -> (usize, usize) {
        (
            (self.h + 2 * self.pad.0 - self.kh) / self.stride.0 + 1,
            (self.w + 2 * self.pad.1 - self.kw) / self.stride.1 + 1,
        )
    }
    /// Exact multiply-accumulate count per sample.
    pub fn macs(&self) -> u64 {
        let (ho, wo) = self.out_dims();
        (ho * wo * self.kh * self.kw * self.cin * self.cout) as u64
    }
    pub fn weight_len(&self) -> usize {
        self.kh * self.kw * self.cin * self.cout
    }
}

/// Shape/behaviour of one depthwise NHWC 2-D convolution.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct DwConv2dSpec {
    pub h: usize,
    pub w: usize,
    pub c: usize,
    pub kh: usize,
    pub kw: usize,
    pub stride: (usize, usize),
    pub pad: (usize, usize),
    pub relu: bool,
}

impl DwConv2dSpec {
    pub fn out_dims(&self) -> (usize, usize) {
        (
            (self.h + 2 * self.pad.0 - self.kh) / self.stride.0 + 1,
            (self.w + 2 * self.pad.1 - self.kw) / self.stride.1 + 1,
        )
    }
    pub fn macs(&self) -> u64 {
        let (ho, wo) = self.out_dims();
        (ho * wo * self.kh * self.kw * self.c) as u64
    }
    pub fn weight_len(&self) -> usize {
        self.kh * self.kw * self.c
    }
}

/// Shape/behaviour of one 1-D convolution.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Conv1dSpec {
    pub l: usize,
    pub cin: usize,
    pub cout: usize,
    pub k: usize,
    pub stride: usize,
    pub pad: usize,
    pub relu: bool,
}

impl Conv1dSpec {
    pub fn out_len(&self) -> usize {
        (self.l + 2 * self.pad - self.k) / self.stride + 1
    }
    pub fn macs(&self) -> u64 {
        (self.out_len() * self.k * self.cin * self.cout) as u64
    }
    pub fn weight_len(&self) -> usize {
        self.k * self.cin * self.cout
    }
}

/// Shape/behaviour of one dense layer (`(m, k) @ (k, n)`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct DenseSpec {
    pub k: usize,
    pub n: usize,
    pub relu: bool,
}

impl DenseSpec {
    /// MACs per input row.
    pub fn macs(&self) -> u64 {
        (self.k * self.n) as u64
    }
    pub fn weight_len(&self) -> usize {
        self.k * self.n
    }
}

// ---------------------------------------------------------------------------
// runtime dispatch
// ---------------------------------------------------------------------------

/// Which kernel implementation executes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dispatch {
    /// Portable reference (fixed summation order, bit-exact).
    Scalar,
    /// `f32x8` + FMA lanes; falls back to scalar off x86_64.
    Avx2,
}

/// Pure dispatch policy, separated from the process environment so
/// tests can sweep it: the env override wins, then hardware detection.
pub fn dispatch_from(force_scalar: Option<&str>, avx2_available: bool) -> Dispatch {
    let forced = force_scalar.is_some_and(|v| !v.is_empty() && v != "0");
    if !forced && avx2_available {
        Dispatch::Avx2
    } else {
        Dispatch::Scalar
    }
}

impl Dispatch {
    /// Runtime selection: `RUST_PALLAS_FORCE_SCALAR=1` forces the
    /// scalar reference; otherwise AVX2+FMA when the CPU has it.
    pub fn detect() -> Dispatch {
        let force = std::env::var("RUST_PALLAS_FORCE_SCALAR").ok();
        dispatch_from(force.as_deref(), avx2_available())
    }

    pub fn name(&self) -> &'static str {
        match self {
            Dispatch::Scalar => "scalar",
            Dispatch::Avx2 => "avx2",
        }
    }

    pub fn conv2d(
        &self,
        x: &[f32],
        batch: usize,
        s: &Conv2dSpec,
        w: &[f32],
        b: &[f32],
    ) -> Vec<f32> {
        match self {
            Dispatch::Scalar => scalar::conv2d(x, batch, s, w, b),
            #[cfg(target_arch = "x86_64")]
            Dispatch::Avx2 => unsafe { avx2::conv2d(x, batch, s, w, b) },
            #[cfg(not(target_arch = "x86_64"))]
            Dispatch::Avx2 => scalar::conv2d(x, batch, s, w, b),
        }
    }

    pub fn dwconv2d(
        &self,
        x: &[f32],
        batch: usize,
        s: &DwConv2dSpec,
        w: &[f32],
        b: &[f32],
    ) -> Vec<f32> {
        match self {
            Dispatch::Scalar => scalar::dwconv2d(x, batch, s, w, b),
            #[cfg(target_arch = "x86_64")]
            Dispatch::Avx2 => unsafe { avx2::dwconv2d(x, batch, s, w, b) },
            #[cfg(not(target_arch = "x86_64"))]
            Dispatch::Avx2 => scalar::dwconv2d(x, batch, s, w, b),
        }
    }

    pub fn conv1d(
        &self,
        x: &[f32],
        batch: usize,
        s: &Conv1dSpec,
        w: &[f32],
        b: &[f32],
    ) -> Vec<f32> {
        match self {
            Dispatch::Scalar => scalar::conv1d(x, batch, s, w, b),
            #[cfg(target_arch = "x86_64")]
            Dispatch::Avx2 => unsafe { avx2::conv1d(x, batch, s, w, b) },
            #[cfg(not(target_arch = "x86_64"))]
            Dispatch::Avx2 => scalar::conv1d(x, batch, s, w, b),
        }
    }

    pub fn dense(&self, x: &[f32], m: usize, s: &DenseSpec, w: &[f32], b: &[f32]) -> Vec<f32> {
        match self {
            Dispatch::Scalar => scalar::dense(x, m, s, w, b),
            #[cfg(target_arch = "x86_64")]
            Dispatch::Avx2 => unsafe { avx2::dense(x, m, s, w, b) },
            #[cfg(not(target_arch = "x86_64"))]
            Dispatch::Avx2 => scalar::dense(x, m, s, w, b),
        }
    }

    pub fn gap(&self, x: &[f32], spatial: usize, c: usize) -> Vec<f32> {
        match self {
            Dispatch::Scalar => scalar::gap(x, spatial, c),
            #[cfg(target_arch = "x86_64")]
            Dispatch::Avx2 => unsafe { avx2::gap(x, spatial, c) },
            #[cfg(not(target_arch = "x86_64"))]
            Dispatch::Avx2 => scalar::gap(x, spatial, c),
        }
    }
}

fn avx2_available() -> bool {
    #[cfg(target_arch = "x86_64")]
    {
        std::arch::is_x86_feature_detected!("avx2") && std::arch::is_x86_feature_detected!("fma")
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        false
    }
}

// ---------------------------------------------------------------------------
// EE head (python ee_head.py): dense -> softmax -> (conf, pred)
// ---------------------------------------------------------------------------

/// Classifier head output: softmax confidence + argmax prediction.
#[derive(Debug, Clone)]
pub struct HeadOut {
    pub probs: Vec<f32>,
    /// max softmax probability
    pub conf: f32,
    /// first argmax index of the logits
    pub pred: i32,
}

/// GAP-feature classifier head: `logits = feats @ w + b`, softmax,
/// confidence = max probability, prediction = first argmax. The
/// softmax reduction itself is always scalar (it is O(classes)); only
/// the dense contraction dispatches.
pub fn ee_head(dispatch: Dispatch, feats: &[f32], w: &[f32], b: &[f32], classes: usize) -> HeadOut {
    let spec = DenseSpec { k: feats.len(), n: classes, relu: false };
    let logits = dispatch.dense(feats, 1, &spec, w, b);
    let m = logits.iter().fold(f32::NEG_INFINITY, |a, &v| a.max(v));
    let mut exps = vec![0.0f32; classes];
    let mut sum = 0.0f32;
    for (e, &l) in exps.iter_mut().zip(&logits) {
        *e = (l - m).exp();
        sum += *e;
    }
    let mut conf = 0.0f32;
    let mut pred = 0usize;
    for (i, e) in exps.iter_mut().enumerate() {
        *e /= sum;
        // first max index, like the python reference's argmax
        if logits[i] > logits[pred] {
            pred = i;
        }
        conf = conf.max(*e);
    }
    HeadOut { probs: exps, conf, pred: pred as i32 }
}

// ---------------------------------------------------------------------------
// native model: seeded layer stacks mirroring the block graph
// ---------------------------------------------------------------------------

/// Scale / determinism knobs of the native backbone.
#[derive(Debug, Clone, Copy)]
pub struct NativeConfig {
    /// Seed for the deterministic weight init (used when no artifact
    /// weights exist — see `NativeModel::set_final_head` for loading
    /// real head weights from `runtime::weights`).
    pub seed: u64,
    /// Input spatial extent (H = W). The block graph's cost model is
    /// resolution-independent ratios; the backend picks the working
    /// resolution.
    pub spatial: usize,
    /// Channel-width cap: bounds the per-request arithmetic so debug
    /// test builds stay fast while release benches run full width.
    pub max_width: usize,
}

impl NativeConfig {
    /// Bench/serve scale: full ResNet widths at 8x8 input.
    pub fn bench(seed: u64) -> Self {
        NativeConfig { seed, spatial: 8, max_width: 64 }
    }
    /// Debug-test scale: tiny widths at 4x4 input.
    pub fn test(seed: u64) -> Self {
        NativeConfig { seed, spatial: 4, max_width: 8 }
    }
}

/// One convolution unit: spec + owned weights.
#[derive(Debug, Clone)]
pub struct Conv2dUnit {
    pub spec: Conv2dSpec,
    pub w: Vec<f32>,
    pub b: Vec<f32>,
}

impl Conv2dUnit {
    fn seeded(spec: Conv2dSpec, rng: &mut Rng) -> Self {
        let fan_in = (spec.kh * spec.kw * spec.cin).max(1);
        let scale = (2.0 / fan_in as f32).sqrt();
        let w = (0..spec.weight_len()).map(|_| (rng.f32() * 2.0 - 1.0) * scale).collect();
        let b = (0..spec.cout).map(|_| (rng.f32() - 0.5) * 0.02).collect();
        Conv2dUnit { spec, w, b }
    }
    fn run(&self, x: &[f32], dispatch: Dispatch) -> Vec<f32> {
        dispatch.conv2d(x, 1, &self.spec, &self.w, &self.b)
    }
}

/// One backbone block: `conv1` (+ReLU), optional `conv2` + residual
/// add (+ReLU) with an optional 1x1 projection on the skip — the
/// native realization of one `BlockGraph` node, shaped exactly like
/// `graph::fine::FineGraph::synthetic_resnet`'s blocks.
#[derive(Debug, Clone)]
pub struct BlockNet {
    pub conv1: Conv2dUnit,
    pub conv2: Option<Conv2dUnit>,
    pub proj: Option<Conv2dUnit>,
    /// Output dims (h, w, c).
    pub out_dims: (usize, usize, usize),
}

impl BlockNet {
    /// Run one sample (NHWC, batch 1). The residual add + final ReLU
    /// are element-wise in a fixed order — identical across dispatch.
    pub fn forward(&self, x: &[f32], dispatch: Dispatch) -> Vec<f32> {
        let y1 = self.conv1.run(x, dispatch);
        let Some(conv2) = &self.conv2 else {
            return y1;
        };
        let mut y2 = conv2.run(&y1, dispatch);
        let skip = match &self.proj {
            Some(p) => p.run(x, dispatch),
            None => x.to_vec(),
        };
        for (o, s) in y2.iter_mut().zip(&skip) {
            *o = (*o + s).max(0.0);
        }
        y2
    }

    /// Exact multiply-accumulate count per sample.
    pub fn macs(&self) -> u64 {
        self.conv1.spec.macs()
            + self.conv2.as_ref().map_or(0, |c| c.spec.macs())
            + self.proj.as_ref().map_or(0, |c| c.spec.macs())
    }
}

/// Seeded dense classifier head over GAP features.
#[derive(Debug, Clone)]
pub struct HeadNet {
    pub c: usize,
    pub classes: usize,
    pub w: Vec<f32>,
    pub b: Vec<f32>,
}

impl HeadNet {
    fn seeded(c: usize, classes: usize, rng: &mut Rng) -> Self {
        let scale = (2.0 / c.max(1) as f32).sqrt();
        let w = (0..c * classes).map(|_| (rng.f32() * 2.0 - 1.0) * scale).collect();
        let b = (0..classes).map(|_| (rng.f32() - 0.5) * 0.02).collect();
        HeadNet { c, classes, w, b }
    }

    /// GAP -> dense -> softmax -> (conf, pred) on a block output.
    pub fn run(&self, fm: &[f32], spatial: usize, dispatch: Dispatch) -> HeadOut {
        let feats = dispatch.gap(fm, spatial, self.c);
        ee_head(dispatch, &feats, &self.w, &self.b, self.classes)
    }

    /// GAP + dense MACs per evaluation.
    pub fn macs(&self) -> u64 {
        (self.c * self.classes) as u64
    }
}

/// The full native backbone: one [`BlockNet`] per coarse block plus a
/// classifier head per block boundary (heads beyond the chosen exits
/// simply go unused by the serving path).
#[derive(Debug, Clone)]
pub struct NativeModel {
    pub blocks: Vec<BlockNet>,
    /// One head per block boundary, matching each block's output width.
    pub heads: Vec<HeadNet>,
    pub num_classes: usize,
    /// Input dims (h, w, c).
    pub in_dims: (usize, usize, usize),
}

impl NativeModel {
    /// Build seeded-weight layer stacks mirroring `graph`. A block
    /// count of `3n + 1` gets the full ResNet shape (stem + 3 stages,
    /// stride-2 stage transitions, residual adds + projections —
    /// exactly `FineGraph::synthetic_resnet`); any other graph gets
    /// one SAME conv per block at that block's `gap_dim` width. Widths
    /// are capped at `cfg.max_width`; weights are a pure function of
    /// `cfg.seed` and the layer index.
    pub fn build(graph: &BlockGraph, cfg: &NativeConfig) -> Self {
        let nb = graph.blocks.len();
        let resnet_n = if nb >= 4 && (nb - 1) % 3 == 0 { Some((nb - 1) / 3) } else { None };
        let mut layer_seed = 0u64;
        let mut unit_rng = |cfg: &NativeConfig| {
            layer_seed += 1;
            Rng::seeded(cfg.seed ^ layer_seed.wrapping_mul(0x9E3779B97F4A7C15))
        };
        let mut blocks = Vec::with_capacity(nb);
        let mut heads = Vec::with_capacity(nb);
        let mut hw = cfg.spatial.max(1);
        let mut cin = 3usize;
        let in_dims = (hw, hw, cin);
        if let Some(n) = resnet_n {
            let widths: Vec<usize> =
                [16usize, 32, 64].iter().map(|&w| w.min(cfg.max_width).max(1)).collect();
            // stem: conv + bias + relu
            let spec = Conv2dSpec {
                h: hw,
                w: hw,
                cin,
                cout: widths[0],
                kh: 3,
                kw: 3,
                stride: (1, 1),
                pad: (1, 1),
                relu: true,
            };
            blocks.push(BlockNet {
                conv1: Conv2dUnit::seeded(spec, &mut unit_rng(cfg)),
                conv2: None,
                proj: None,
                out_dims: (hw, hw, widths[0]),
            });
            cin = widths[0];
            for (si, &w) in widths.iter().enumerate() {
                for bi in 0..n {
                    let stride = if si > 0 && bi == 0 { 2 } else { 1 };
                    let in_hw = hw;
                    if stride == 2 {
                        hw = (hw / 2).max(1);
                    }
                    let conv1 = Conv2dSpec {
                        h: in_hw,
                        w: in_hw,
                        cin,
                        cout: w,
                        kh: 3,
                        kw: 3,
                        stride: (stride, stride),
                        pad: (1, 1),
                        relu: true,
                    };
                    let (h1, w1) = conv1.out_dims();
                    let conv2 = Conv2dSpec {
                        h: h1,
                        w: w1,
                        cin: w,
                        cout: w,
                        kh: 3,
                        kw: 3,
                        stride: (1, 1),
                        pad: (1, 1),
                        relu: false,
                    };
                    let proj = (stride == 2 || cin != w).then_some(Conv2dSpec {
                        h: in_hw,
                        w: in_hw,
                        cin,
                        cout: w,
                        kh: 1,
                        kw: 1,
                        stride: (stride, stride),
                        pad: (0, 0),
                        relu: false,
                    });
                    blocks.push(BlockNet {
                        conv1: Conv2dUnit::seeded(conv1, &mut unit_rng(cfg)),
                        conv2: Some(Conv2dUnit::seeded(conv2, &mut unit_rng(cfg))),
                        proj: proj.map(|p| Conv2dUnit::seeded(p, &mut unit_rng(cfg))),
                        out_dims: (h1, w1, w),
                    });
                    hw = h1;
                    cin = w;
                }
            }
        } else {
            for block in &graph.blocks {
                let cout = block.gap_dim.min(cfg.max_width).max(1);
                let spec = Conv2dSpec {
                    h: hw,
                    w: hw,
                    cin,
                    cout,
                    kh: 3,
                    kw: 3,
                    stride: (1, 1),
                    pad: (1, 1),
                    relu: true,
                };
                blocks.push(BlockNet {
                    conv1: Conv2dUnit::seeded(spec, &mut unit_rng(cfg)),
                    conv2: None,
                    proj: None,
                    out_dims: (hw, hw, cout),
                });
                cin = cout;
            }
        }
        let num_classes = graph.num_classes.max(2);
        for b in &blocks {
            heads.push(HeadNet::seeded(b.out_dims.2, num_classes, &mut unit_rng(cfg)));
        }
        NativeModel { blocks, heads, num_classes, in_dims }
    }

    /// Install real (artifact) weights on the final classifier head —
    /// the `runtime::weights` path. Ignored with a `false` return when
    /// the dimensions don't match this model's final width.
    pub fn set_final_head(&mut self, w: &[f32], b: &[f32]) -> bool {
        let Some(head) = self.heads.last_mut() else {
            return false;
        };
        if w.len() != head.c * head.classes || b.len() != head.classes {
            return false;
        }
        head.w = w.to_vec();
        head.b = b.to_vec();
        true
    }

    /// Install real exit-head weights at a block boundary (e.g. from a
    /// solution's trained `ExitHead`s). Same dimension guard.
    pub fn set_exit_head(&mut self, loc: usize, w: &[f32], b: &[f32]) -> bool {
        let Some(head) = self.heads.get_mut(loc) else {
            return false;
        };
        if w.len() != head.c * head.classes || b.len() != head.classes {
            return false;
        }
        head.w = w.to_vec();
        head.b = b.to_vec();
        true
    }

    /// Exact backbone MACs per block per sample.
    pub fn block_macs(&self) -> Vec<u64> {
        self.blocks.iter().map(|b| b.macs()).collect()
    }

    /// Per-segment MACs (backbone blocks + the boundary head evaluated
    /// at the segment end) under `mapping` — the arithmetic one
    /// request spends in each serving stage.
    pub fn segment_macs(&self, mapping: &crate::mapping::Mapping) -> Vec<u64> {
        let nseg = mapping.exits.len() + 1;
        (0..nseg)
            .map(|seg| {
                let (lo, hi) = mapping.segment(seg, self.blocks.len());
                let backbone: u64 = self.blocks[lo..=hi].iter().map(|b| b.macs()).sum();
                backbone + self.heads[hi].macs()
            })
            .collect()
    }

    /// Run one sample through every block, returning each boundary's
    /// GAP feature vector plus the final head's (conf, pred) — the
    /// native path for exit-feature extraction (`na::features`).
    pub fn forward_all(&self, x: &[f32], dispatch: Dispatch) -> (Vec<Vec<f32>>, f32, i32) {
        let mut gaps = Vec::with_capacity(self.blocks.len());
        let mut fm = self.blocks[0].forward(x, dispatch);
        let mut dims = self.blocks[0].out_dims;
        gaps.push(dispatch.gap(&fm, dims.0 * dims.1, dims.2));
        for b in &self.blocks[1..] {
            fm = b.forward(&fm, dispatch);
            dims = b.out_dims;
            gaps.push(dispatch.gap(&fm, dims.0 * dims.1, dims.2));
        }
        let head = self.heads.last().expect("model has blocks");
        let out = ee_head(dispatch, gaps.last().unwrap(), &head.w, &head.b, head.classes);
        (gaps, out.conf, out.pred)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::fine::FineGraph;

    #[test]
    fn dispatch_policy_honors_env_then_hardware() {
        assert_eq!(dispatch_from(None, true), Dispatch::Avx2);
        assert_eq!(dispatch_from(None, false), Dispatch::Scalar);
        assert_eq!(dispatch_from(Some("1"), true), Dispatch::Scalar);
        assert_eq!(dispatch_from(Some(""), true), Dispatch::Avx2);
        assert_eq!(dispatch_from(Some("0"), true), Dispatch::Avx2);
        // detect() must never pick an unsupported path
        if !super::avx2_available() {
            assert_eq!(Dispatch::detect(), Dispatch::Scalar);
        }
    }

    #[test]
    fn conv2d_identity_kernel_passes_input_through() {
        // 1x1 kernel with identity channel mix: output == input
        let s = Conv2dSpec {
            h: 3,
            w: 3,
            cin: 2,
            cout: 2,
            kh: 1,
            kw: 1,
            stride: (1, 1),
            pad: (0, 0),
            relu: false,
        };
        let x: Vec<f32> = (0..18).map(|i| i as f32 * 0.25 - 2.0).collect();
        let w = vec![1.0, 0.0, 0.0, 1.0]; // (1,1,2,2) identity
        let b = vec![0.0, 0.0];
        let y = scalar::conv2d(&x, 1, &s, &w, &b);
        assert_eq!(y, x);
    }

    #[test]
    fn conv2d_hand_example_with_padding() {
        // 3x3 all-ones kernel over a 2x2 single-channel image, SAME
        // pad: each output is the sum of the in-range neighbourhood
        let s = Conv2dSpec {
            h: 2,
            w: 2,
            cin: 1,
            cout: 1,
            kh: 3,
            kw: 3,
            stride: (1, 1),
            pad: (1, 1),
            relu: false,
        };
        let x = vec![1.0, 2.0, 3.0, 4.0];
        let w = vec![1.0; 9];
        let y = scalar::conv2d(&x, 1, &s, &w, &[0.0]);
        assert_eq!(y, vec![10.0, 10.0, 10.0, 10.0]);
    }

    #[test]
    fn dense_matches_hand_matmul() {
        let s = DenseSpec { k: 3, n: 2, relu: false };
        let x = vec![1.0, 2.0, 3.0];
        let w = vec![1.0, 0.5, 0.0, -1.0, 2.0, 0.25]; // (3,2)
        let b = vec![0.5, -0.5];
        let y = scalar::dense(&x, 1, &s, &w, &b);
        assert_eq!(y, vec![1.0 + 6.0 + 0.5, 0.5 - 2.0 + 0.75 - 0.5]);
    }

    #[test]
    fn relu_clamps_negative_outputs() {
        let s = DenseSpec { k: 1, n: 2, relu: true };
        let y = scalar::dense(&[1.0], 1, &s, &[-2.0, 3.0], &[0.0, 0.0]);
        assert_eq!(y, vec![0.0, 3.0]);
    }

    #[test]
    fn ee_head_is_a_distribution_with_first_argmax() {
        let feats = vec![1.0, -0.5, 0.25];
        // weights force a tie between classes 0 and 2
        let w = vec![1.0, 0.0, 1.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0];
        let b = vec![0.0, -1.0, 0.0];
        let out = ee_head(Dispatch::Scalar, &feats, &w, &b, 3);
        let sum: f32 = out.probs.iter().sum();
        assert!((sum - 1.0).abs() < 1e-6, "softmax must normalize: {sum}");
        assert_eq!(out.pred, 0, "tie resolves to the first index");
        let max = out.probs.iter().fold(f32::NEG_INFINITY, |a, &p| a.max(p));
        assert_eq!(out.conf, max, "confidence is the max probability");
    }

    #[test]
    fn resnet_shaped_model_mirrors_fine_graph_macs() {
        // at the fine graph's native resolution (32x32, full widths)
        // the seeded model's per-block MACs equal the fusion pass's
        // analytic block costs exactly: the cost model the search
        // optimizes is the arithmetic the backend runs
        let n = 2;
        let graph = BlockGraph::synthetic_resnet(10, n);
        let cfg = NativeConfig { seed: 7, spatial: 32, max_width: 64 };
        let model = NativeModel::build(&graph, &cfg);
        assert_eq!(model.blocks.len(), graph.blocks.len());
        let fine = FineGraph::synthetic_resnet(10, n).fuse();
        for (i, (b, fb)) in model.blocks.iter().zip(&fine.blocks).enumerate() {
            assert_eq!(b.macs(), fb.macs, "block {i} ({})", fb.name);
        }
    }

    #[test]
    fn generic_fallback_covers_non_resnet_block_counts() {
        let mut graph = BlockGraph::synthetic_resnet(10, 2);
        graph.blocks.pop(); // 6 blocks: not 3n+1
        let model = NativeModel::build(&graph, &NativeConfig::test(3));
        assert_eq!(model.blocks.len(), graph.blocks.len());
        for b in &model.blocks {
            assert!(b.conv2.is_none(), "generic blocks are single convs");
        }
    }

    #[test]
    fn weight_init_is_seed_deterministic() {
        let graph = BlockGraph::synthetic_resnet(10, 2);
        let a = NativeModel::build(&graph, &NativeConfig::test(11));
        let b = NativeModel::build(&graph, &NativeConfig::test(11));
        let c = NativeModel::build(&graph, &NativeConfig::test(12));
        assert_eq!(a.blocks[1].conv1.w, b.blocks[1].conv1.w);
        assert_eq!(a.heads[0].w, b.heads[0].w);
        assert_ne!(a.blocks[1].conv1.w, c.blocks[1].conv1.w);
    }

    #[test]
    fn head_weight_installation_guards_dimensions() {
        let graph = BlockGraph::synthetic_resnet(4, 2);
        let mut model = NativeModel::build(&graph, &NativeConfig::test(5));
        let c = model.heads.last().unwrap().c;
        let k = model.num_classes;
        assert!(model.set_final_head(&vec![0.5; c * k], &vec![0.0; k]));
        assert_eq!(model.heads.last().unwrap().w, vec![0.5; c * k]);
        assert!(!model.set_final_head(&vec![0.5; c * k + 1], &vec![0.0; k]));
        assert!(model.set_exit_head(1, &vec![0.25; model.heads[1].c * k], &vec![0.0; k]));
        assert!(!model.set_exit_head(99, &[], &[]));
    }

    #[test]
    fn forward_all_emits_one_gap_per_block() {
        let graph = BlockGraph::synthetic_resnet(5, 2);
        let cfg = NativeConfig::test(9);
        let model = NativeModel::build(&graph, &cfg);
        let x = vec![0.1f32; cfg.spatial * cfg.spatial * 3];
        let (gaps, conf, pred) = model.forward_all(&x, Dispatch::Scalar);
        assert_eq!(gaps.len(), model.blocks.len());
        for (g, b) in gaps.iter().zip(&model.blocks) {
            assert_eq!(g.len(), b.out_dims.2);
        }
        assert!(conf > 0.0 && conf <= 1.0);
        assert!((0..5).contains(&pred));
    }

    #[test]
    fn segment_macs_cover_the_backbone_plus_boundary_heads() {
        let graph = BlockGraph::synthetic_resnet(10, 2);
        let model = NativeModel::build(&graph, &NativeConfig::test(2));
        let mapping =
            crate::mapping::Mapping { exits: vec![2, 4], assignment: vec![0, 1, 2] };
        let per_seg = model.segment_macs(&mapping);
        assert_eq!(per_seg.len(), 3);
        let backbone: u64 = model.block_macs().iter().sum();
        let heads: u64 = model.heads[2].macs() + model.heads[4].macs()
            + model.heads.last().unwrap().macs();
        assert_eq!(per_seg.iter().sum::<u64>(), backbone + heads);
    }
}
