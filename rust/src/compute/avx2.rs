//! AVX2 (f32x8 + FMA) kernels, selected at runtime by
//! [`super::Dispatch::detect`].
//!
//! Vectorization axis is the **output channel**: the weight layouts
//! put `cout` (or `c` for depthwise) innermost, so eight output
//! channels load as one contiguous `f32x8` lane while the input
//! activation broadcasts. Each lane accumulates in exactly the scalar
//! reference order (taps outer, input channels inner ascending); the
//! only numerical difference is FMA rounding, bounded by the kernel
//! parity battery at 1e-5 relative. The channel remainder (`% 8`)
//! falls back to the scalar inner loop in the same order. GAP uses
//! additions only — no FMA — and is bit-exact vs scalar.
//!
//! Every function is `unsafe` because of `#[target_feature]`: callers
//! must have verified AVX2+FMA support (the dispatch enum does).
#![cfg(target_arch = "x86_64")]

use std::arch::x86_64::*;

use super::{Conv1dSpec, Conv2dSpec, DenseSpec, DwConv2dSpec};

/// NHWC conv2d, AVX2 lanes over `cout`.
///
/// # Safety
/// The running CPU must support AVX2 and FMA
/// (`is_x86_feature_detected!("avx2")` + `("fma")`).
#[target_feature(enable = "avx2,fma")]
pub unsafe fn conv2d(
    x: &[f32],
    batch: usize,
    s: &Conv2dSpec,
    wgt: &[f32],
    bias: &[f32],
) -> Vec<f32> {
    let (ho, wo) = s.out_dims();
    let (sh, sw) = s.stride;
    let (ph, pw) = s.pad;
    let lanes = s.cout / 8 * 8;
    let mut out = vec![0.0f32; batch * ho * wo * s.cout];
    for bi in 0..batch {
        let xb = &x[bi * s.h * s.w * s.cin..][..s.h * s.w * s.cin];
        let ob = &mut out[bi * ho * wo * s.cout..][..ho * wo * s.cout];
        for oy in 0..ho {
            for ox in 0..wo {
                let o = (oy * wo + ox) * s.cout;
                let mut co = 0usize;
                while co < lanes {
                    let mut acc = _mm256_setzero_ps();
                    for ky in 0..s.kh {
                        let iy = (oy * sh + ky) as isize - ph as isize;
                        if iy < 0 || iy >= s.h as isize {
                            continue;
                        }
                        for kx in 0..s.kw {
                            let ix = (ox * sw + kx) as isize - pw as isize;
                            if ix < 0 || ix >= s.w as isize {
                                continue;
                            }
                            let xoff = (iy as usize * s.w + ix as usize) * s.cin;
                            let woff = (ky * s.kw + kx) * s.cin * s.cout + co;
                            for ci in 0..s.cin {
                                let xv = _mm256_set1_ps(xb[xoff + ci]);
                                let wv = _mm256_loadu_ps(wgt.as_ptr().add(woff + ci * s.cout));
                                acc = _mm256_fmadd_ps(xv, wv, acc);
                            }
                        }
                    }
                    acc = _mm256_add_ps(acc, _mm256_loadu_ps(bias.as_ptr().add(co)));
                    if s.relu {
                        acc = _mm256_max_ps(acc, _mm256_setzero_ps());
                    }
                    _mm256_storeu_ps(ob.as_mut_ptr().add(o + co), acc);
                    co += 8;
                }
                for co in lanes..s.cout {
                    let mut acc = 0.0f32;
                    for ky in 0..s.kh {
                        let iy = (oy * sh + ky) as isize - ph as isize;
                        if iy < 0 || iy >= s.h as isize {
                            continue;
                        }
                        for kx in 0..s.kw {
                            let ix = (ox * sw + kx) as isize - pw as isize;
                            if ix < 0 || ix >= s.w as isize {
                                continue;
                            }
                            let xoff = (iy as usize * s.w + ix as usize) * s.cin;
                            let woff = (ky * s.kw + kx) * s.cin * s.cout + co;
                            for ci in 0..s.cin {
                                acc += xb[xoff + ci] * wgt[woff + ci * s.cout];
                            }
                        }
                    }
                    acc += bias[co];
                    ob[o + co] = if s.relu { acc.max(0.0) } else { acc };
                }
            }
        }
    }
    out
}

/// Depthwise NHWC conv2d, AVX2 lanes over `c`.
///
/// # Safety
/// The running CPU must support AVX2 and FMA.
#[target_feature(enable = "avx2,fma")]
pub unsafe fn dwconv2d(
    x: &[f32],
    batch: usize,
    s: &DwConv2dSpec,
    wgt: &[f32],
    bias: &[f32],
) -> Vec<f32> {
    let (ho, wo) = s.out_dims();
    let (sh, sw) = s.stride;
    let (ph, pw) = s.pad;
    let lanes = s.c / 8 * 8;
    let mut out = vec![0.0f32; batch * ho * wo * s.c];
    for bi in 0..batch {
        let xb = &x[bi * s.h * s.w * s.c..][..s.h * s.w * s.c];
        let ob = &mut out[bi * ho * wo * s.c..][..ho * wo * s.c];
        for oy in 0..ho {
            for ox in 0..wo {
                let o = (oy * wo + ox) * s.c;
                let mut ci = 0usize;
                while ci < lanes {
                    let mut acc = _mm256_setzero_ps();
                    for ky in 0..s.kh {
                        let iy = (oy * sh + ky) as isize - ph as isize;
                        if iy < 0 || iy >= s.h as isize {
                            continue;
                        }
                        for kx in 0..s.kw {
                            let ix = (ox * sw + kx) as isize - pw as isize;
                            if ix < 0 || ix >= s.w as isize {
                                continue;
                            }
                            let xv = _mm256_loadu_ps(
                                xb.as_ptr().add((iy as usize * s.w + ix as usize) * s.c + ci),
                            );
                            let wv =
                                _mm256_loadu_ps(wgt.as_ptr().add((ky * s.kw + kx) * s.c + ci));
                            acc = _mm256_fmadd_ps(xv, wv, acc);
                        }
                    }
                    acc = _mm256_add_ps(acc, _mm256_loadu_ps(bias.as_ptr().add(ci)));
                    if s.relu {
                        acc = _mm256_max_ps(acc, _mm256_setzero_ps());
                    }
                    _mm256_storeu_ps(ob.as_mut_ptr().add(o + ci), acc);
                    ci += 8;
                }
                for ci in lanes..s.c {
                    let mut acc = 0.0f32;
                    for ky in 0..s.kh {
                        let iy = (oy * sh + ky) as isize - ph as isize;
                        if iy < 0 || iy >= s.h as isize {
                            continue;
                        }
                        for kx in 0..s.kw {
                            let ix = (ox * sw + kx) as isize - pw as isize;
                            if ix < 0 || ix >= s.w as isize {
                                continue;
                            }
                            acc += xb[(iy as usize * s.w + ix as usize) * s.c + ci]
                                * wgt[(ky * s.kw + kx) * s.c + ci];
                        }
                    }
                    acc += bias[ci];
                    ob[o + ci] = if s.relu { acc.max(0.0) } else { acc };
                }
            }
        }
    }
    out
}

/// 1-D conv, AVX2 lanes over `cout`.
///
/// # Safety
/// The running CPU must support AVX2 and FMA.
#[target_feature(enable = "avx2,fma")]
pub unsafe fn conv1d(
    x: &[f32],
    batch: usize,
    s: &Conv1dSpec,
    wgt: &[f32],
    bias: &[f32],
) -> Vec<f32> {
    let lo = s.out_len();
    let lanes = s.cout / 8 * 8;
    let mut out = vec![0.0f32; batch * lo * s.cout];
    for bi in 0..batch {
        let xb = &x[bi * s.l * s.cin..][..s.l * s.cin];
        let ob = &mut out[bi * lo * s.cout..][..lo * s.cout];
        for op in 0..lo {
            let o = op * s.cout;
            let mut co = 0usize;
            while co < lanes {
                let mut acc = _mm256_setzero_ps();
                for kt in 0..s.k {
                    let ip = (op * s.stride + kt) as isize - s.pad as isize;
                    if ip < 0 || ip >= s.l as isize {
                        continue;
                    }
                    let xoff = ip as usize * s.cin;
                    let woff = kt * s.cin * s.cout + co;
                    for ci in 0..s.cin {
                        let xv = _mm256_set1_ps(xb[xoff + ci]);
                        let wv = _mm256_loadu_ps(wgt.as_ptr().add(woff + ci * s.cout));
                        acc = _mm256_fmadd_ps(xv, wv, acc);
                    }
                }
                acc = _mm256_add_ps(acc, _mm256_loadu_ps(bias.as_ptr().add(co)));
                if s.relu {
                    acc = _mm256_max_ps(acc, _mm256_setzero_ps());
                }
                _mm256_storeu_ps(ob.as_mut_ptr().add(o + co), acc);
                co += 8;
            }
            for co in lanes..s.cout {
                let mut acc = 0.0f32;
                for kt in 0..s.k {
                    let ip = (op * s.stride + kt) as isize - s.pad as isize;
                    if ip < 0 || ip >= s.l as isize {
                        continue;
                    }
                    let xoff = ip as usize * s.cin;
                    let woff = kt * s.cin * s.cout + co;
                    for ci in 0..s.cin {
                        acc += xb[xoff + ci] * wgt[woff + ci * s.cout];
                    }
                }
                acc += bias[co];
                ob[o + co] = if s.relu { acc.max(0.0) } else { acc };
            }
        }
    }
    out
}

/// Dense `(m, k) @ (k, n)`, AVX2 lanes over `n`.
///
/// # Safety
/// The running CPU must support AVX2 and FMA.
#[target_feature(enable = "avx2,fma")]
pub unsafe fn dense(x: &[f32], m: usize, s: &DenseSpec, wgt: &[f32], bias: &[f32]) -> Vec<f32> {
    let lanes = s.n / 8 * 8;
    let mut out = vec![0.0f32; m * s.n];
    for i in 0..m {
        let xr = &x[i * s.k..][..s.k];
        let ob = &mut out[i * s.n..][..s.n];
        let mut j = 0usize;
        while j < lanes {
            let mut acc = _mm256_setzero_ps();
            for (ki, &xv) in xr.iter().enumerate() {
                let xv = _mm256_set1_ps(xv);
                let wv = _mm256_loadu_ps(wgt.as_ptr().add(ki * s.n + j));
                acc = _mm256_fmadd_ps(xv, wv, acc);
            }
            acc = _mm256_add_ps(acc, _mm256_loadu_ps(bias.as_ptr().add(j)));
            if s.relu {
                acc = _mm256_max_ps(acc, _mm256_setzero_ps());
            }
            _mm256_storeu_ps(ob.as_mut_ptr().add(j), acc);
            j += 8;
        }
        for j in lanes..s.n {
            let mut acc = 0.0f32;
            for (ki, &xv) in xr.iter().enumerate() {
                acc += xv * wgt[ki * s.n + j];
            }
            acc += bias[j];
            ob[j] = if s.relu { acc.max(0.0) } else { acc };
        }
    }
    out
}

/// Global average pool, AVX2 lanes over `c` — additions only, in the
/// scalar order, so the result is bit-exact vs [`super::scalar::gap`].
///
/// # Safety
/// The running CPU must support AVX2 and FMA.
#[target_feature(enable = "avx2,fma")]
pub unsafe fn gap(x: &[f32], spatial: usize, c: usize) -> Vec<f32> {
    let inv = 1.0f32 / spatial.max(1) as f32;
    let lanes = c / 8 * 8;
    let mut out = vec![0.0f32; c];
    let mut ci = 0usize;
    while ci < lanes {
        let mut acc = _mm256_setzero_ps();
        for p in 0..spatial {
            acc = _mm256_add_ps(acc, _mm256_loadu_ps(x.as_ptr().add(p * c + ci)));
        }
        acc = _mm256_mul_ps(acc, _mm256_set1_ps(inv));
        _mm256_storeu_ps(out.as_mut_ptr().add(ci), acc);
        ci += 8;
    }
    for ci in lanes..c {
        let mut acc = 0.0f32;
        for p in 0..spatial {
            acc += x[p * c + ci];
        }
        out[ci] = acc * inv;
    }
    out
}
