//! Portable scalar kernels — the **bit-exact reference** for the
//! native backend.
//!
//! Every kernel fixes one summation order per output element — filter
//! taps outermost (ky, then kx), input channels innermost ascending —
//! mirroring the Python reference kernels in
//! `python/compile/kernels/`, which accumulate per-tap contractions
//! into the output. The AVX2 path ([`super::avx2`]) walks the *same*
//! order per output channel lane; its only deviation is fused
//! multiply-add rounding, which is why kernel parity is pinned at a
//! relative tolerance instead of bit equality (GAP is add-only and
//! stays bit-exact). Out-of-image taps are skipped, never multiplied
//! as zeros, in both paths.

use super::{Conv1dSpec, Conv2dSpec, DenseSpec, DwConv2dSpec};

/// NHWC conv2d: x `(batch, h, w, cin)`, weights `(kh, kw, cin, cout)`,
/// bias `(cout)`; returns `(batch, ho, wo, cout)`.
pub fn conv2d(x: &[f32], batch: usize, s: &Conv2dSpec, wgt: &[f32], bias: &[f32]) -> Vec<f32> {
    let (ho, wo) = s.out_dims();
    let (sh, sw) = s.stride;
    let (ph, pw) = s.pad;
    let mut out = vec![0.0f32; batch * ho * wo * s.cout];
    for bi in 0..batch {
        let xb = &x[bi * s.h * s.w * s.cin..][..s.h * s.w * s.cin];
        let ob = &mut out[bi * ho * wo * s.cout..][..ho * wo * s.cout];
        for oy in 0..ho {
            for ox in 0..wo {
                let o = (oy * wo + ox) * s.cout;
                for co in 0..s.cout {
                    let mut acc = 0.0f32;
                    for ky in 0..s.kh {
                        let iy = (oy * sh + ky) as isize - ph as isize;
                        if iy < 0 || iy >= s.h as isize {
                            continue;
                        }
                        for kx in 0..s.kw {
                            let ix = (ox * sw + kx) as isize - pw as isize;
                            if ix < 0 || ix >= s.w as isize {
                                continue;
                            }
                            let xoff = (iy as usize * s.w + ix as usize) * s.cin;
                            let woff = ((ky * s.kw + kx) * s.cin) * s.cout + co;
                            for ci in 0..s.cin {
                                acc += xb[xoff + ci] * wgt[woff + ci * s.cout];
                            }
                        }
                    }
                    acc += bias[co];
                    ob[o + co] = if s.relu { acc.max(0.0) } else { acc };
                }
            }
        }
    }
    out
}

/// Depthwise NHWC conv2d: x `(batch, h, w, c)`, weights `(kh, kw, c)`,
/// bias `(c)`; returns `(batch, ho, wo, c)`.
pub fn dwconv2d(x: &[f32], batch: usize, s: &DwConv2dSpec, wgt: &[f32], bias: &[f32]) -> Vec<f32> {
    let (ho, wo) = s.out_dims();
    let (sh, sw) = s.stride;
    let (ph, pw) = s.pad;
    let mut out = vec![0.0f32; batch * ho * wo * s.c];
    for bi in 0..batch {
        let xb = &x[bi * s.h * s.w * s.c..][..s.h * s.w * s.c];
        let ob = &mut out[bi * ho * wo * s.c..][..ho * wo * s.c];
        for oy in 0..ho {
            for ox in 0..wo {
                let o = (oy * wo + ox) * s.c;
                for ci in 0..s.c {
                    let mut acc = 0.0f32;
                    for ky in 0..s.kh {
                        let iy = (oy * sh + ky) as isize - ph as isize;
                        if iy < 0 || iy >= s.h as isize {
                            continue;
                        }
                        for kx in 0..s.kw {
                            let ix = (ox * sw + kx) as isize - pw as isize;
                            if ix < 0 || ix >= s.w as isize {
                                continue;
                            }
                            acc += xb[(iy as usize * s.w + ix as usize) * s.c + ci]
                                * wgt[(ky * s.kw + kx) * s.c + ci];
                        }
                    }
                    acc += bias[ci];
                    ob[o + ci] = if s.relu { acc.max(0.0) } else { acc };
                }
            }
        }
    }
    out
}

/// 1-D conv: x `(batch, l, cin)`, weights `(k, cin, cout)`, bias
/// `(cout)`; returns `(batch, lo, cout)`.
pub fn conv1d(x: &[f32], batch: usize, s: &Conv1dSpec, wgt: &[f32], bias: &[f32]) -> Vec<f32> {
    let lo = s.out_len();
    let mut out = vec![0.0f32; batch * lo * s.cout];
    for bi in 0..batch {
        let xb = &x[bi * s.l * s.cin..][..s.l * s.cin];
        let ob = &mut out[bi * lo * s.cout..][..lo * s.cout];
        for op in 0..lo {
            let o = op * s.cout;
            for co in 0..s.cout {
                let mut acc = 0.0f32;
                for kt in 0..s.k {
                    let ip = (op * s.stride + kt) as isize - s.pad as isize;
                    if ip < 0 || ip >= s.l as isize {
                        continue;
                    }
                    let xoff = ip as usize * s.cin;
                    let woff = kt * s.cin * s.cout + co;
                    for ci in 0..s.cin {
                        acc += xb[xoff + ci] * wgt[woff + ci * s.cout];
                    }
                }
                acc += bias[co];
                ob[o + co] = if s.relu { acc.max(0.0) } else { acc };
            }
        }
    }
    out
}

/// Dense: x `(m, k)` @ w `(k, n)` + b `(n)`; returns `(m, n)`.
pub fn dense(x: &[f32], m: usize, s: &DenseSpec, wgt: &[f32], bias: &[f32]) -> Vec<f32> {
    let mut out = vec![0.0f32; m * s.n];
    for i in 0..m {
        let xr = &x[i * s.k..][..s.k];
        let or_ = &mut out[i * s.n..][..s.n];
        for j in 0..s.n {
            let mut acc = 0.0f32;
            for (ki, &xv) in xr.iter().enumerate() {
                acc += xv * wgt[ki * s.n + j];
            }
            acc += bias[j];
            or_[j] = if s.relu { acc.max(0.0) } else { acc };
        }
    }
    out
}

/// Global average pool over the spatial axis: x `(spatial, c)` ->
/// `(c)`. Additions run in ascending spatial order per channel — the
/// AVX2 path keeps the identical order, so GAP is bit-exact across
/// dispatch.
pub fn gap(x: &[f32], spatial: usize, c: usize) -> Vec<f32> {
    let inv = 1.0f32 / spatial.max(1) as f32;
    let mut out = vec![0.0f32; c];
    for (ci, o) in out.iter_mut().enumerate() {
        let mut acc = 0.0f32;
        for p in 0..spatial {
            acc += x[p * c + ci];
        }
        *o = acc * inv;
    }
    out
}
