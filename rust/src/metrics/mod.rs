//! Classification metrics: accuracy, macro precision/recall, confusion
//! matrix — the quantities Table 2 reports per created EENN.

#[derive(Debug, Clone)]
pub struct Confusion {
    pub k: usize,
    /// m[actual * k + predicted]
    pub m: Vec<u64>,
}

impl Confusion {
    pub fn new(k: usize) -> Self {
        Confusion { k, m: vec![0; k * k] }
    }

    pub fn add(&mut self, actual: usize, predicted: usize) {
        assert!(actual < self.k && predicted < self.k);
        self.m[actual * self.k + predicted] += 1;
    }

    pub fn total(&self) -> u64 {
        self.m.iter().sum()
    }

    pub fn accuracy(&self) -> f64 {
        let correct: u64 = (0..self.k).map(|i| self.m[i * self.k + i]).sum();
        let total = self.total();
        if total == 0 {
            return 0.0;
        }
        correct as f64 / total as f64
    }

    /// Macro-averaged precision over classes that were ever predicted
    /// or present (absent classes are skipped, matching scikit's
    /// zero_division behaviour closely enough for trend comparison).
    pub fn macro_precision(&self) -> f64 {
        let mut sum = 0.0;
        let mut cnt = 0usize;
        for c in 0..self.k {
            let tp = self.m[c * self.k + c] as f64;
            let pred: u64 = (0..self.k).map(|a| self.m[a * self.k + c]).sum();
            let actual: u64 = (0..self.k).map(|p| self.m[c * self.k + p]).sum();
            if pred == 0 && actual == 0 {
                continue;
            }
            sum += if pred == 0 { 0.0 } else { tp / pred as f64 };
            cnt += 1;
        }
        if cnt == 0 {
            0.0
        } else {
            sum / cnt as f64
        }
    }

    pub fn macro_recall(&self) -> f64 {
        let mut sum = 0.0;
        let mut cnt = 0usize;
        for c in 0..self.k {
            let tp = self.m[c * self.k + c] as f64;
            let actual: u64 = (0..self.k).map(|p| self.m[c * self.k + p]).sum();
            if actual == 0 {
                continue;
            }
            sum += tp / actual as f64;
            cnt += 1;
        }
        if cnt == 0 {
            0.0
        } else {
            sum / cnt as f64
        }
    }
}

/// Full quality metrics of an evaluated (E)ENN on a test set.
#[derive(Debug, Clone, Default)]
pub struct Quality {
    pub accuracy: f64,
    pub precision: f64,
    pub recall: f64,
}

impl Quality {
    pub fn from_confusion(c: &Confusion) -> Self {
        Quality {
            accuracy: c.accuracy(),
            precision: c.macro_precision(),
            recall: c.macro_recall(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn perfect_classifier() {
        let mut c = Confusion::new(3);
        for i in 0..3 {
            for _ in 0..10 {
                c.add(i, i);
            }
        }
        assert_eq!(c.accuracy(), 1.0);
        assert_eq!(c.macro_precision(), 1.0);
        assert_eq!(c.macro_recall(), 1.0);
    }

    #[test]
    fn known_confusion() {
        // class 0: 8 right, 2 -> 1 ; class 1: 10 right ; class 2: 5 right, 5 -> 0
        let mut c = Confusion::new(3);
        for _ in 0..8 {
            c.add(0, 0);
        }
        for _ in 0..2 {
            c.add(0, 1);
        }
        for _ in 0..10 {
            c.add(1, 1);
        }
        for _ in 0..5 {
            c.add(2, 2);
        }
        for _ in 0..5 {
            c.add(2, 0);
        }
        assert!((c.accuracy() - 23.0 / 30.0).abs() < 1e-12);
        // precision: c0 8/13, c1 10/12, c2 5/5
        let p = (8.0 / 13.0 + 10.0 / 12.0 + 1.0) / 3.0;
        assert!((c.macro_precision() - p).abs() < 1e-12);
        // recall: 8/10, 10/10, 5/10
        let r = (0.8 + 1.0 + 0.5) / 3.0;
        assert!((c.macro_recall() - r).abs() < 1e-12);
    }

    #[test]
    fn absent_class_skipped() {
        let mut c = Confusion::new(5);
        c.add(0, 0);
        c.add(1, 1);
        assert_eq!(c.accuracy(), 1.0);
        assert_eq!(c.macro_precision(), 1.0);
    }
}
