#!/usr/bin/env sh
# Arm (or deliberately refresh) the CI bench-regression baselines.
#
# Regenerates every smoke-scale bench artifact exactly the way the
# bench-smoke CI job does, then copies each into ci/baselines/ via
# `xtask bench-update`. Run from the repo root on the machine class
# whose numbers should gate (wall-clock fields carry a ±50% band, so
# any reasonably quiet host arms a usable gate; deterministic fields
# are host-independent by construction).
#
#   ./ci/baselines/arm.sh            # arm only missing baselines
#   ./ci/baselines/arm.sh --refresh  # rewrite all of them
set -eu

refresh=0
[ "${1:-}" = "--refresh" ] && refresh=1

cargo bench --bench search_cost -- --smoke --threads 1,2
cargo bench --bench serving_throughput -- --smoke
cargo bench --bench hotpath -- --smoke
cargo bench --bench hotpath -- --backend native
cargo run --release -p eenn-na --bin repro -- scenarios --smoke
cargo run --release -p eenn-na --bin repro -- scenarios --smoke \
  --only stress_fog_shed --out BENCH_scenarios_shed.json
cargo run --release -p eenn-na --bin repro -- scenarios --smoke \
  --only multi_tenant_fog --out BENCH_scenarios_multi_tenant.json
cargo run --release -p eenn-na --bin repro -- scenarios --smoke \
  --only overload_storm --out BENCH_scenarios_storm.json
cargo run --release -p eenn-na --bin repro -- scenarios --smoke \
  --only fleet_rebalance --out BENCH_scenarios_fleet.json
cargo run --release -p eenn-na --bin repro -- scenarios --smoke \
  --only mesh_cifar --out BENCH_scenarios_mesh.json
cargo run --release -p eenn-na --bin repro -- scenarios --smoke --joint \
  --only mesh_cifar_joint --out BENCH_scenarios_mesh_joint.json

# the bench list comes from xtask — the same GATED_BENCHES constant the
# CI regression gate (`bench-check --all`) and arming step iterate
for b in $(cargo run --release -p xtask -- bench-list); do
  if [ "$refresh" = 1 ] || [ ! -f "ci/baselines/BENCH_$b.json" ]; then
    cargo run --release -p xtask -- bench-update \
      --fresh "BENCH_$b.json" --baseline "ci/baselines/BENCH_$b.json"
  else
    echo "ci/baselines/BENCH_$b.json already armed (use --refresh to rewrite)"
  fi
done

echo "done — commit ci/baselines/ to end bootstrap mode"
